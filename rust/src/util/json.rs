//! Minimal-but-complete JSON library (substrate; see DESIGN.md §2).
//!
//! The exaCB protocol (§V-B of the paper) is a hierarchical JSON data
//! model, and this environment vendors no `serde_json`, so we implement
//! the value model, a recursive-descent parser, and compact + pretty
//! writers ourselves. Object key order is preserved (important for
//! byte-stable protocol documents committed to the `exacb.data` store).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve insertion order via a `Vec` of pairs
/// plus a lazy index — protocol documents are small (tens of keys), so
/// linear probing beats a map until proven otherwise (see §Perf).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum Json {
    #[default]
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Error with byte offset + line/column context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----------------------------------------------------- constructors

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Builder-style insert; panics if `self` is not an object.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        self.insert(key, value.into());
        self
    }

    /// Insert or replace a key in an object.
    pub fn insert(&mut self, key: &str, value: impl Into<Json>) {
        match self {
            Json::Obj(pairs) => {
                let value = value.into();
                if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::insert on non-object"),
        }
    }

    pub fn push(&mut self, value: impl Into<Json>) {
        match self {
            Json::Arr(items) => items.push(value.into()),
            _ => panic!("Json::push on non-array"),
        }
    }

    // ------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: &str) -> Option<&mut Json> {
        match self {
            Json::Obj(pairs) => pairs.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(items) => items.get(i),
            _ => None,
        }
    }

    /// JSON-pointer-ish path access: `report.pointer("/data/0/runtime")`.
    pub fn pointer(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('/').filter(|s| !s.is_empty()) {
            cur = match cur {
                Json::Obj(_) => cur.get(seg)?,
                Json::Arr(_) => cur.idx(seg.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(pairs) => Some(pairs),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: string value at `key` (objects only).
    pub fn str_of(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    pub fn f64_of(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    pub fn u64_of(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(Json::as_u64)
    }

    pub fn bool_of(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Json::as_bool)
    }

    /// Deep sort of object keys — canonical form for hashing/storing.
    pub fn canonicalize(&self) -> Json {
        match self {
            Json::Obj(pairs) => {
                let sorted: BTreeMap<&String, &Json> =
                    pairs.iter().map(|(k, v)| (k, v)).collect();
                Json::Obj(
                    sorted
                        .into_iter()
                        .map(|(k, v)| (k.clone(), v.canonicalize()))
                        .collect(),
                )
            }
            Json::Arr(items) => Json::Arr(items.iter().map(Json::canonicalize).collect()),
            other => other.clone(),
        }
    }

    // --------------------------------------------------------- writers

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::with_capacity(128);
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::with_capacity(256);
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---------------------------------------------------------- parser

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; protocol documents must stay parseable.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        let s = format!("{n}");
        out.push_str(&s);
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        let consumed = &self.bytes[..self.pos.min(self.bytes.len())];
        let line = consumed.iter().filter(|&&b| b == b'\n').count() + 1;
        let col = consumed
            .iter()
            .rev()
            .take_while(|&&b| b != b'\n')
            .count()
            + 1;
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
            line,
            col,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("invalid literal (expected {word})")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            out.push(cp);
                            continue;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // fast path: consume the whole contiguous run of
                    // plain characters in one slice (validating UTF-8
                    // once per run, not per character — see
                    // EXPERIMENTS.md §Perf, protocol-parse iteration)
                    let start = self.pos;
                    while let Some(&b) = self.bytes.get(self.pos) {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        // self.pos is at 'u'
        let hex4 = |p: &Self, at: usize| -> Result<u32, JsonError> {
            let slice = p
                .bytes
                .get(at..at + 4)
                .ok_or_else(|| p.err("truncated \\u escape"))?;
            let s = std::str::from_utf8(slice).map_err(|_| p.err("bad \\u escape"))?;
            u32::from_str_radix(s, 16).map_err(|_| p.err("bad \\u escape"))
        };
        let hi = hex4(self, self.pos + 1)?;
        self.pos += 5;
        let cp = if (0xd800..0xdc00).contains(&hi) {
            // surrogate pair
            if self.bytes.get(self.pos) == Some(&b'\\')
                && self.bytes.get(self.pos + 1) == Some(&b'u')
            {
                let lo = hex4(self, self.pos + 2)?;
                self.pos += 6;
                0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
            } else {
                return Err(self.err("unpaired surrogate"));
            }
        } else {
            hi
        };
        char::from_u32(cp).ok_or_else(|| self.err("invalid code point"))
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ----------------------------------------------------------- From impls

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i32> for Json {
    fn from(n: i32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(items: Vec<T>) -> Json {
        Json::Arr(items.into_iter().map(Into::into).collect())
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(items: &[T]) -> Json {
        Json::Arr(items.iter().cloned().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":{"d":true}}"#).unwrap();
        assert_eq!(v.pointer("/a/2/b"), Some(&Json::Null));
        assert_eq!(v.pointer("/c/d"), Some(&Json::Bool(true)));
        assert_eq!(v.pointer("/a/9"), None);
    }

    #[test]
    fn key_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn canonicalize_sorts_keys() {
        let v = Json::parse(r#"{"z":1,"a":{"y":0,"b":1}}"#).unwrap();
        assert_eq!(
            v.canonicalize().to_string(),
            r#"{"a":{"b":1,"y":0},"z":1}"#
        );
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn errors_carry_position() {
        let e = Json::parse("{\n  \"a\": }").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("unexpected"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn builder_and_accessors() {
        let v = Json::obj()
            .set("name", "exacb")
            .set("n", 3u64)
            .set("ok", true)
            .set("list", vec![1i64, 2, 3]);
        assert_eq!(v.str_of("name"), Some("exacb"));
        assert_eq!(v.u64_of("n"), Some(3));
        assert_eq!(v.bool_of("ok"), Some(true));
        assert_eq!(v.get("list").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn insert_replaces() {
        let mut v = Json::obj().set("k", 1i64);
        v.insert("k", 2i64);
        assert_eq!(v.u64_of("k"), Some(2));
        assert_eq!(v.as_obj().unwrap().len(), 1);
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_is_reparseable() {
        let v = Json::parse(r#"{"a":[1,{"b":[]},[]],"c":{}}"#).unwrap();
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn large_ints_preserved() {
        let v = Json::parse("1234567890123").unwrap();
        assert_eq!(v.as_i64(), Some(1234567890123));
        assert_eq!(v.to_string(), "1234567890123");
    }
}
