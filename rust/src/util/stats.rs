//! Small statistics toolkit used by the analysis orchestrators and the
//! bench harness: summary statistics, percentiles, linear regression, and
//! a CUSUM-style changepoint detector (regression detection, §IV-F).

/// Summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summary(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary {
            n: 0,
            mean: f64::NAN,
            sd: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
        };
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        sd: var.sqrt(),
        min: xs.iter().cloned().fold(f64::MAX, f64::min),
        max: xs.iter().cloned().fold(f64::MIN, f64::max),
    }
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Ordinary least squares: returns (slope, intercept, r2).
pub fn linear_fit(points: &[(f64, f64)]) -> (f64, f64, f64) {
    let n = points.len() as f64;
    if points.len() < 2 {
        return (0.0, points.first().map(|p| p.1).unwrap_or(0.0), 0.0);
    }
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (0.0, sy / n, 0.0);
    }
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    let my = sy / n;
    let ss_tot: f64 = points.iter().map(|p| (p.1 - my) * (p.1 - my)).sum();
    let ss_res: f64 = points
        .iter()
        .map(|p| {
            let e = p.1 - (slope * p.0 + intercept);
            e * e
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (slope, intercept, r2)
}

/// Detected level shift in a series.
#[derive(Debug, Clone, PartialEq)]
pub struct Changepoint {
    pub index: usize,
    /// Mean before / after the shift.
    pub before: f64,
    pub after: f64,
    /// |after-before| in units of pooled standard deviation.
    pub magnitude_sd: f64,
}

/// Binary-segmentation changepoint detection: recursively find the split
/// that maximizes the between-segment mean difference, accepting splits
/// whose shift exceeds `threshold_sd` *noise* standard deviations. Noise
/// is estimated from the median absolute first difference (robust to the
/// level shifts we are trying to detect). Used by the time-series
/// orchestrator to flag regressions/recoveries (Fig. 4).
pub fn changepoints(xs: &[f64], threshold_sd: f64) -> Vec<Changepoint> {
    let mut found = Vec::new();
    let noise = diff_noise(xs);
    segment(xs, 0, &mut found, threshold_sd, noise, 0);
    found.sort_by_key(|c| c.index);
    found
}

/// Robust noise estimate: median(|x[i+1]-x[i]|) / (sqrt(2) * 0.6745),
/// the MAD-based sigma of the differenced series. Level shifts contribute
/// only one sample to the differences, so the median ignores them.
fn diff_noise(xs: &[f64]) -> f64 {
    if xs.len() < 3 {
        return f64::MAX;
    }
    let diffs: Vec<f64> = xs.windows(2).map(|w| (w[1] - w[0]).abs()).collect();
    let mad = median(&diffs);
    let scale = xs.iter().map(|x| x.abs()).fold(0.0, f64::max).max(1e-300);
    (mad / (std::f64::consts::SQRT_2 * 0.6745)).max(1e-9 * scale)
}

fn segment(
    xs: &[f64],
    offset: usize,
    out: &mut Vec<Changepoint>,
    thr: f64,
    noise: f64,
    depth: usize,
) {
    const MIN_SEG: usize = 5;
    if xs.len() < 2 * MIN_SEG || depth > 6 {
        return;
    }
    let mut best: Option<(usize, f64, f64, f64)> = None; // (idx, score, mb, ma)
    for i in MIN_SEG..xs.len() - MIN_SEG {
        let (a, b) = xs.split_at(i);
        let sa = summary(a);
        let sb = summary(b);
        let score = (sb.mean - sa.mean).abs() / noise;
        if best.map(|(_, s, _, _)| score > s).unwrap_or(true) {
            best = Some((i, score, sa.mean, sb.mean));
        }
    }
    if let Some((i, score, mb, ma)) = best {
        if score >= thr {
            out.push(Changepoint {
                index: offset + i,
                before: mb,
                after: ma,
                magnitude_sd: score,
            });
            let (a, b) = xs.split_at(i);
            segment(a, offset, out, thr, noise, depth + 1);
            segment(b, offset + i, out, thr, noise, depth + 1);
        }
    }
}

/// Geometric mean (cross-application aggregate, §VI-A).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summary(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact_line() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 3.0 * i as f64 + 1.0)).collect();
        let (m, b, r2) = linear_fit(&pts);
        assert!((m - 3.0).abs() < 1e-9);
        assert!((b - 1.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn changepoint_detects_shift() {
        let mut xs = vec![10.0; 30];
        xs.extend(vec![14.0; 30]);
        // add tiny deterministic wiggle so sd > 0
        for (i, x) in xs.iter_mut().enumerate() {
            *x += (i % 3) as f64 * 0.01;
        }
        let cps = changepoints(&xs, 5.0);
        assert_eq!(cps.len(), 1, "{cps:?}");
        assert!((28..=32).contains(&cps[0].index));
        assert!(cps[0].after > cps[0].before);
    }

    #[test]
    fn changepoint_regression_and_recovery() {
        // level 10 -> 7 (regression) -> 10 (recovery): Fig. 4 shape
        let mut xs = Vec::new();
        for i in 0..90 {
            let base = if (30..60).contains(&i) { 7.0 } else { 10.0 };
            xs.push(base + (i % 4) as f64 * 0.02);
        }
        let cps = changepoints(&xs, 5.0);
        assert!(cps.len() >= 2, "{cps:?}");
        assert!(cps.iter().any(|c| c.after < c.before));
        assert!(cps.iter().any(|c| c.after > c.before));
    }

    #[test]
    fn stable_series_has_no_changepoints() {
        let xs: Vec<f64> = (0..60).map(|i| 100.0 + (i % 5) as f64 * 0.1).collect();
        assert!(changepoints(&xs, 6.0).is_empty());
    }

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_nan() {
        assert!(summary(&[]).mean.is_nan());
        assert!(percentile(&[], 50.0).is_nan());
        assert!(geomean(&[]).is_nan());
    }
}
