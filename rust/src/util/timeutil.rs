//! Simulated-calendar utilities.
//!
//! The simulators run on a virtual clock of seconds since a fixed epoch
//! (2026-01-01T00:00:00Z — the start of the paper's Fig. 3/4 time span).
//! Protocol timestamps are ISO-8601 strings derived from that clock; the
//! time-series components parse them back for `time_span` filtering.

pub const EPOCH_YEAR: i64 = 2026;
pub const SECS_PER_DAY: i64 = 86_400;

/// Days in each month for a given year.
fn month_days(year: i64) -> [i64; 12] {
    let leap = (year % 4 == 0 && year % 100 != 0) || year % 400 == 0;
    [
        31,
        if leap { 29 } else { 28 },
        31,
        30,
        31,
        30,
        31,
        31,
        30,
        31,
        30,
        31,
    ]
}

/// A simulated instant: seconds since 2026-01-01T00:00:00Z (may be negative
/// for pre-epoch dates, e.g. software stage 2025 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub i64);

impl SimTime {
    pub fn from_days(days: i64) -> SimTime {
        SimTime(days * SECS_PER_DAY)
    }

    pub fn day(&self) -> i64 {
        self.0.div_euclid(SECS_PER_DAY)
    }

    pub fn add_secs(&self, s: i64) -> SimTime {
        SimTime(self.0 + s)
    }

    /// (year, month, day) of the civil date.
    pub fn ymd(&self) -> (i64, i64, i64) {
        let mut days = self.day();
        let mut year = EPOCH_YEAR;
        loop {
            let ydays: i64 = month_days(year).iter().sum();
            if days >= ydays {
                days -= ydays;
                year += 1;
            } else if days < 0 {
                year -= 1;
                days += month_days(year).iter().sum::<i64>();
            } else {
                break;
            }
        }
        let mut month = 1;
        for md in month_days(year) {
            if days < md {
                break;
            }
            days -= md;
            month += 1;
        }
        (year, month, days + 1)
    }

    /// `YYYY-MM-DD`.
    pub fn date_string(&self) -> String {
        let (y, m, d) = self.ymd();
        format!("{y:04}-{m:02}-{d:02}")
    }

    /// `YYYY-MM-DDTHH:MM:SSZ`.
    pub fn iso8601(&self) -> String {
        let (y, m, d) = self.ymd();
        let secs = self.0.rem_euclid(SECS_PER_DAY);
        format!(
            "{y:04}-{m:02}-{d:02}T{:02}:{:02}:{:02}Z",
            secs / 3600,
            (secs % 3600) / 60,
            secs % 60
        )
    }

    /// Parse `YYYY-MM-DD` or full ISO-8601 (Z suffix optional).
    pub fn parse(text: &str) -> Option<SimTime> {
        let t = text.trim().trim_end_matches('Z');
        let (date, time) = match t.split_once('T') {
            Some((d, tm)) => (d, Some(tm)),
            None => (t, None),
        };
        let mut parts = date.split('-');
        let y: i64 = parts.next()?.parse().ok()?;
        let m: i64 = parts.next()?.parse().ok()?;
        let d: i64 = parts.next()?.parse().ok()?;
        if parts.next().is_some() || !(1..=12).contains(&m) {
            return None;
        }
        if d < 1 || d > month_days(y)[(m - 1) as usize] {
            return None;
        }
        let mut days: i64 = 0;
        if y >= EPOCH_YEAR {
            for yy in EPOCH_YEAR..y {
                days += month_days(yy).iter().sum::<i64>();
            }
        } else {
            for yy in y..EPOCH_YEAR {
                days -= month_days(yy).iter().sum::<i64>();
            }
        }
        days += month_days(y)[..(m - 1) as usize].iter().sum::<i64>();
        days += d - 1;
        let mut secs = days * SECS_PER_DAY;
        if let Some(tm) = time {
            let mut hms = tm.split(':');
            let h: i64 = hms.next()?.parse().ok()?;
            let mi: i64 = hms.next().unwrap_or("0").parse().ok()?;
            let s: i64 = hms
                .next()
                .unwrap_or("0")
                .split('.')
                .next()?
                .parse()
                .ok()?;
            secs += h * 3600 + mi * 60 + s;
        }
        Some(SimTime(secs))
    }
}

/// Format seconds as `HH:MM:SS` (job walltimes).
pub fn fmt_duration(secs: i64) -> String {
    format!(
        "{:02}:{:02}:{:02}",
        secs / 3600,
        (secs % 3600) / 60,
        secs % 60
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_is_jan1_2026() {
        assert_eq!(SimTime(0).date_string(), "2026-01-01");
        assert_eq!(SimTime(0).iso8601(), "2026-01-01T00:00:00Z");
    }

    #[test]
    fn day_arithmetic() {
        assert_eq!(SimTime::from_days(31).date_string(), "2026-02-01");
        assert_eq!(SimTime::from_days(59).date_string(), "2026-03-01"); // 2026 not leap
        assert_eq!(SimTime::from_days(365).date_string(), "2027-01-01");
    }

    #[test]
    fn leap_year_2028() {
        // 2026: 365, 2027: 365, then Feb 2028 has 29 days
        let feb29 = SimTime::parse("2028-02-29").unwrap();
        assert_eq!(feb29.date_string(), "2028-02-29");
        assert!(SimTime::parse("2026-02-29").is_none());
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["2026-01-01", "2026-04-01", "2026-12-31", "2027-06-15"] {
            assert_eq!(SimTime::parse(s).unwrap().date_string(), s);
        }
        let t = SimTime::parse("2026-03-05T13:45:10Z").unwrap();
        assert_eq!(t.iso8601(), "2026-03-05T13:45:10Z");
    }

    #[test]
    fn pre_epoch_dates() {
        let t = SimTime::parse("2025-12-31").unwrap();
        assert_eq!(t.day(), -1);
        assert_eq!(t.date_string(), "2025-12-31");
        let t2 = SimTime::parse("2025-01-01").unwrap();
        assert_eq!(t2.date_string(), "2025-01-01");
    }

    #[test]
    fn ordering_matches_chronology() {
        let a = SimTime::parse("2026-01-01").unwrap();
        let b = SimTime::parse("2026-04-01").unwrap();
        assert!(a < b);
    }

    #[test]
    fn invalid_dates_rejected() {
        assert!(SimTime::parse("2026-13-01").is_none());
        assert!(SimTime::parse("2026-00-10").is_none());
        assert!(SimTime::parse("garbage").is_none());
        assert!(SimTime::parse("2026-04-31").is_none());
    }

    #[test]
    fn duration_format() {
        assert_eq!(fmt_duration(3725), "01:02:05");
        assert_eq!(fmt_duration(0), "00:00:00");
    }
}
