//! Definition discovery and parsing (DESIGN.md §15).
//!
//! `load_dir` walks a directory tree for `*.toml` files (sorted by path,
//! so collection layout is deterministic), parses each with
//! [`crate::util::tomlite`], converts the `[[app]]` / `[[machine]]` /
//! `[[engine]]` tables into the typed model, and finishes with
//! [`super::validate::validate`]. Every failure — I/O, TOML syntax,
//! missing or mistyped key, semantic rule — names the file it came from.

use super::model::{AppDef, DefSet, EngineDef, MachineDef};
use super::validate::{validate, verr, ValidationError};
use crate::cluster::{GpuGen, NetworkLink, PowerModel};
use crate::util::json::Json;
use crate::util::tomlite;
use crate::workloads::portfolio::Maturity;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// Why a definition directory failed to load.
#[derive(Debug, Clone, PartialEq)]
pub enum DefsError {
    /// The directory (or a file in it) could not be read.
    Io { path: String, msg: String },
    /// The directory exists but contains no `*.toml` files.
    Empty { path: String },
    /// A file failed TOML parsing.
    Toml { file: String, err: tomlite::TomlError },
    /// Files parsed but the definitions are wrong; every error names its
    /// file, table, and key.
    Invalid(Vec<ValidationError>),
}

impl fmt::Display for DefsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DefsError::Io { path, msg } => write!(f, "cannot read '{path}': {msg}"),
            DefsError::Empty { path } => {
                write!(f, "definition directory '{path}' contains no *.toml files")
            }
            DefsError::Toml { file, err } => write!(f, "{file}: {err}"),
            DefsError::Invalid(errs) => {
                for (i, e) in errs.iter().enumerate() {
                    if i > 0 {
                        writeln!(f)?;
                    }
                    write!(f, "{e}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for DefsError {}

/// Discover and load a definition directory from disk.
pub fn load_dir(dir: &str) -> Result<DefSet, DefsError> {
    let root = Path::new(dir);
    if !root.is_dir() {
        return Err(DefsError::Io {
            path: dir.to_string(),
            msg: "not a directory".to_string(),
        });
    }
    let mut paths = Vec::new();
    discover(root, &mut paths)?;
    paths.sort();
    if paths.is_empty() {
        return Err(DefsError::Empty {
            path: dir.to_string(),
        });
    }
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let text = fs::read_to_string(&p).map_err(|e| DefsError::Io {
            path: p.display().to_string(),
            msg: e.to_string(),
        })?;
        files.push((p.display().to_string(), text));
    }
    parse_files(&files)
}

fn discover(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), DefsError> {
    let io = |e: std::io::Error| DefsError::Io {
        path: dir.display().to_string(),
        msg: e.to_string(),
    };
    for entry in fs::read_dir(dir).map_err(io)? {
        let path = entry.map_err(io)?.path();
        if path.is_dir() {
            discover(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "toml") {
            out.push(path);
        }
    }
    Ok(())
}

/// Parse already-read `(file name, contents)` pairs into a validated
/// [`DefSet`]. This is the filesystem-free core of [`load_dir`], shared
/// with the differential tests and the `perf_defs` bench.
pub fn parse_files(files: &[(String, String)]) -> Result<DefSet, DefsError> {
    let mut set = DefSet::default();
    let mut errs = Vec::new();
    for (file, text) in files {
        let doc = tomlite::parse(text).map_err(|err| DefsError::Toml {
            file: file.clone(),
            err,
        })?;
        let Some(pairs) = doc.as_obj() else {
            continue;
        };
        for (key, value) in pairs {
            match key.as_str() {
                "app" => each_table(file, key, value, &mut errs, |t, e| {
                    set.apps.push(app_from(file, t, e));
                }),
                "machine" => each_table(file, key, value, &mut errs, |t, e| {
                    set.machines.push(machine_from(file, t, e));
                }),
                "engine" => each_table(file, key, value, &mut errs, |t, e| {
                    set.engines.push(engine_from(file, t, e));
                }),
                other => errs.push(verr(
                    file,
                    &format!("[{other}]"),
                    "",
                    "unknown top-level table (expected [[app]], [[machine]], [[engine]])",
                )),
            }
        }
    }
    if !errs.is_empty() {
        return Err(DefsError::Invalid(errs));
    }
    validate(&set).map_err(DefsError::Invalid)?;
    Ok(set)
}

fn each_table(
    file: &str,
    key: &str,
    value: &Json,
    errs: &mut Vec<ValidationError>,
    mut f: impl FnMut(&Json, &mut Vec<ValidationError>),
) {
    match value.as_arr() {
        Some(items) => {
            for item in items {
                if item.as_obj().is_some() {
                    f(item, errs);
                } else {
                    errs.push(verr(file, &format!("[[{key}]]"), "", "entry is not a table"));
                }
            }
        }
        None => errs.push(verr(
            file,
            &format!("[{key}]"),
            "",
            format!("must be an array of tables ([[{key}]])"),
        )),
    }
}

/// Error-accumulating field reader: missing or mistyped keys push a
/// named [`ValidationError`] and yield a placeholder, so one pass over a
/// broken file reports *every* problem.
struct Fields<'a> {
    file: &'a str,
    table: String,
    errs: &'a mut Vec<ValidationError>,
}

impl<'a> Fields<'a> {
    fn err(&mut self, key: &str, msg: impl Into<String>) {
        // pointer paths ("/parameters/steps") display dotted, TOML-style
        let key = key.trim_start_matches('/').replace('/', ".");
        self.errs.push(verr(self.file, &self.table, &key, msg));
    }

    fn req_str(&mut self, t: &Json, key: &str) -> String {
        match t.pointer(key).and_then(Json::as_str) {
            Some(s) => s.to_string(),
            None => {
                self.err(key, "missing or not a string");
                String::new()
            }
        }
    }

    fn req_f64(&mut self, t: &Json, key: &str) -> f64 {
        match t.pointer(key).and_then(Json::as_f64) {
            Some(v) => v,
            None => {
                self.err(key, "missing or not a number");
                f64::NAN
            }
        }
    }

    fn req_u64(&mut self, t: &Json, key: &str) -> u64 {
        match t.pointer(key).and_then(Json::as_u64) {
            Some(v) => v,
            None => {
                self.err(key, "missing or not a non-negative integer");
                0
            }
        }
    }

    fn opt_bool(&mut self, t: &Json, key: &str, default: bool) -> bool {
        match t.pointer(key) {
            None => default,
            Some(v) => match v.as_bool() {
                Some(b) => b,
                None => {
                    self.err(key, "not a boolean");
                    default
                }
            },
        }
    }

    fn str_arr(&mut self, t: &Json, key: &str) -> Vec<String> {
        let Some(items) = t.pointer(key).and_then(Json::as_arr) else {
            self.err(key, "missing or not an array of strings");
            return Vec::new();
        };
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            match item.as_str() {
                Some(s) => out.push(s.to_string()),
                None => self.err(key, "array element is not a string"),
            }
        }
        out
    }
}

fn app_from(file: &str, t: &Json, errs: &mut Vec<ValidationError>) -> AppDef {
    let name = t.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
    let mut f = Fields {
        file,
        table: format!("[[app]] '{name}'"),
        errs,
    };
    if t.get("name").and_then(Json::as_str).is_none() {
        f.err("name", "missing or not a string");
    }
    let rung = f.req_str(t, "maturity");
    let maturity = match Maturity::parse(&rung) {
        Ok(m) => m,
        Err(_) => {
            if !rung.is_empty() {
                f.err(
                    "maturity",
                    format!(
                        "'{rung}' is not a maturity rung \
                         (runnability|instrumentability|reproducibility)"
                    ),
                );
            }
            Maturity::Runnability
        }
    };
    AppDef {
        domain: f.req_str(t, "domain"),
        maturity,
        engine: f.req_str(t, "engine"),
        nodes: f.req_u64(t, "nodes"),
        gflops_total: f.req_f64(t, "/parameters/gflops_total"),
        serial_frac: f.req_f64(t, "/parameters/serial_frac"),
        mem_bound: f.req_f64(t, "/parameters/mem_bound"),
        comm_mb: f.req_f64(t, "/parameters/comm_mb"),
        steps: f.req_u64(t, "/parameters/steps"),
        weak: f.opt_bool(t, "/parameters/weak", false),
        failure_rate: f.req_f64(t, "/behavior/failure_rate"),
        primary_metric: f.req_str(t, "/metrics/primary"),
        record_metrics: f.str_arr(t, "/metrics/record"),
        name,
        file: file.to_string(),
    }
}

fn machine_from(file: &str, t: &Json, errs: &mut Vec<ValidationError>) -> MachineDef {
    let name = t.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
    let mut f = Fields {
        file,
        table: format!("[[machine]] '{name}'"),
        errs,
    };
    if t.get("name").and_then(Json::as_str).is_none() {
        f.err("name", "missing or not a string");
    }
    let gpu = {
        let s = f.req_str(t, "gpu");
        match GpuGen::parse(&s) {
            Some(g) => g,
            None => {
                if !s.is_empty() {
                    f.err("gpu", format!("unknown GPU generation '{s}'"));
                }
                GpuGen::Ampere
            }
        }
    };
    let network = network_from(t, &mut f);
    let power = power_from(t, &mut f);
    MachineDef {
        version: f.req_str(t, "version"),
        gpu,
        nodes: f.req_u64(t, "nodes"),
        gpus_per_node: f.req_u64(t, "gpus_per_node"),
        cores_per_node: f.req_u64(t, "cores_per_node"),
        partitions: f.str_arr(t, "partitions"),
        network,
        power,
        stream_efficiency: f.req_f64(t, "stream_efficiency"),
        noise_sigma: f.req_f64(t, "noise_sigma"),
        perf_factor: f.req_f64(t, "perf_factor"),
        name,
        file: file.to_string(),
    }
}

fn network_from(t: &Json, f: &mut Fields) -> NetworkLink {
    match t.get("network") {
        Some(Json::Str(s)) => NetworkLink::preset(s).unwrap_or_else(|| {
            f.err("network", format!("unknown network preset '{s}'"));
            NetworkLink::ndr400()
        }),
        Some(sub) if sub.as_obj().is_some() => NetworkLink {
            name: f.req_str(t, "/network/name"),
            latency_us: f.req_f64(t, "/network/latency_us"),
            bw_gbs: f.req_f64(t, "/network/bw_gbs"),
            rndv_handshake_us: f.req_f64(t, "/network/rndv_handshake_us"),
            eager_bw_fraction: f.req_f64(t, "/network/eager_bw_fraction"),
            eager_per_kb_us: f.req_f64(t, "/network/eager_per_kb_us"),
            default_rndv_thresh: f.req_u64(t, "/network/default_rndv_thresh"),
        },
        _ => {
            f.err("network", "missing/invalid; give a preset name or a [machine.network] table");
            NetworkLink::ndr400()
        }
    }
}

fn power_from(t: &Json, f: &mut Fields) -> PowerModel {
    match t.get("power") {
        Some(Json::Str(s)) => PowerModel::preset(s).unwrap_or_else(|| {
            f.err("power", format!("unknown power preset '{s}'"));
            PowerModel::a100()
        }),
        Some(sub) if sub.as_obj().is_some() => PowerModel {
            idle_w: f.req_f64(t, "/power/idle_w"),
            tdp_w: f.req_f64(t, "/power/tdp_w"),
            nominal_mhz: f.req_f64(t, "/power/nominal_mhz"),
            min_mhz: f.req_f64(t, "/power/min_mhz"),
            sensor_noise_w: f.req_f64(t, "/power/sensor_noise_w"),
        },
        _ => {
            f.err("power", "missing/invalid; give a preset name or a [machine.power] table");
            PowerModel::a100()
        }
    }
}

fn engine_from(file: &str, t: &Json, errs: &mut Vec<ValidationError>) -> EngineDef {
    let name = t.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
    let mut f = Fields {
        file,
        table: format!("[[engine]] '{name}'"),
        errs,
    };
    if t.get("name").and_then(Json::as_str).is_none() {
        f.err("name", "missing or not a string");
    }
    EngineDef {
        command: f.req_str(t, "command"),
        description: f.req_str(t, "description"),
        name,
        file: file.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"
[[engine]]
name = "simapp"
command = "simapp"
description = "parameterised scalable app"

[[machine]]
name = "toy"
version = "2026.1"
gpu = "gh200"
nodes = 8
gpus_per_node = 4
cores_per_node = 72
partitions = ["all", "devel"]
network = "ndr400"
power = "gh200"
stream_efficiency = 0.85
noise_sigma = 0.006
perf_factor = 1.0

[[app]]
name = "toy-01"
domain = "cfd"
maturity = "runnability"
engine = "simapp"
nodes = 2

[app.parameters]
gflops_total = 10000.0
serial_frac = 0.01
mem_bound = 0.4
comm_mb = 32.0
steps = 50

[app.behavior]
failure_rate = 0.02

[app.metrics]
primary = "tts"
record = ["tts", "gflops_rate"]
"#;

    fn files(text: &str) -> Vec<(String, String)> {
        vec![("dir/defs.toml".to_string(), text.to_string())]
    }

    #[test]
    fn good_file_parses() {
        let set = parse_files(&files(GOOD)).unwrap();
        assert_eq!(set.apps.len(), 1);
        assert_eq!(set.machines.len(), 1);
        assert_eq!(set.engines.len(), 1);
        let a = &set.apps[0];
        assert_eq!(a.name, "toy-01");
        assert_eq!(a.maturity, Maturity::Runnability);
        assert_eq!(a.steps, 50);
        assert!(!a.weak);
        assert_eq!(a.file, "dir/defs.toml");
        let m = &set.machines[0];
        assert_eq!(m.network, NetworkLink::ndr400());
        assert_eq!(m.power, PowerModel::gh200());
        assert_eq!(m.partitions, vec!["all".to_string(), "devel".to_string()]);
    }

    #[test]
    fn full_network_and_power_tables_accepted() {
        // a [machine.network] header ends the flat key run, so it goes
        // after the machine's last flat key; inline power stays flat
        let text = GOOD
            .replace("network = \"ndr400\"\n", "")
            .replace(
                "power = \"gh200\"",
                "power = { idle_w = 75.0, tdp_w = 700.0, nominal_mhz = 1980.0, \
                 min_mhz = 345.0, sensor_noise_w = 6.0 }",
            )
            .replace(
                "perf_factor = 1.0",
                "perf_factor = 1.0\n\n[machine.network]\nname = \"IB-NDR400\"\n\
                 latency_us = 0.9\nbw_gbs = 48.0\nrndv_handshake_us = 2.2\n\
                 eager_bw_fraction = 0.55\neager_per_kb_us = 0.012\n\
                 default_rndv_thresh = 8192",
            );
        let set = parse_files(&files(&text)).unwrap();
        assert_eq!(set.machines[0].network, NetworkLink::ndr400());
        assert_eq!(set.machines[0].power, PowerModel::gh200());
    }

    #[test]
    fn missing_keys_named_with_file_table_key() {
        let text = GOOD.replace("gflops_total = 10000.0\n", "");
        let err = parse_files(&files(&text)).unwrap_err();
        let DefsError::Invalid(errs) = err else {
            panic!("want Invalid, got {err:?}");
        };
        let shown: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(
            shown.iter().any(|s| s.contains("dir/defs.toml")
                && s.contains("[[app]] 'toy-01'")
                && s.contains("gflops_total")),
            "{shown:?}"
        );
    }

    #[test]
    fn toml_syntax_error_names_file_and_line() {
        let err = parse_files(&files("[[app]\nname = 3")).unwrap_err();
        let DefsError::Toml { file, err } = err else {
            panic!("want Toml");
        };
        assert_eq!(file, "dir/defs.toml");
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn unknown_top_level_table_rejected() {
        let err = parse_files(&files(&format!("{GOOD}\n[[application]]\nname = \"x\"\n")))
            .unwrap_err();
        let DefsError::Invalid(errs) = err else {
            panic!("want Invalid");
        };
        assert!(errs.iter().any(|e| e.table == "[application]"), "{errs:?}");
    }

    #[test]
    fn bad_preset_and_maturity_named() {
        let text = GOOD
            .replace("network = \"ndr400\"", "network = \"token-ring\"")
            .replace("maturity = \"runnability\"", "maturity = \"perfection\"");
        let DefsError::Invalid(errs) = parse_files(&files(&text)).unwrap_err() else {
            panic!("want Invalid");
        };
        assert!(errs.iter().any(|e| e.key == "network" && e.msg.contains("token-ring")));
        assert!(errs.iter().any(|e| e.key == "maturity" && e.msg.contains("perfection")));
    }

    #[test]
    fn load_dir_unknown_path_is_io_error() {
        let err = load_dir("/definitely/not/a/dir").unwrap_err();
        assert!(matches!(err, DefsError::Io { .. }));
        assert!(err.to_string().contains("/definitely/not/a/dir"));
    }

    #[test]
    fn load_dir_empty_dir_is_loud() {
        let dir = std::env::temp_dir().join("exacb_defs_empty_test");
        fs::create_dir_all(&dir).unwrap();
        let err = load_dir(dir.to_str().unwrap()).unwrap_err();
        assert!(matches!(err, DefsError::Empty { .. }), "{err:?}");
        assert!(err.to_string().contains("no *.toml files"));
    }

    #[test]
    fn load_dir_reads_nested_tree_sorted() {
        let dir = std::env::temp_dir().join("exacb_defs_tree_test");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(dir.join("sub")).unwrap();
        // split GOOD: engines+machines at top level, app in a subdir
        let split = GOOD.find("[[app]]").unwrap();
        fs::write(dir.join("base.toml"), &GOOD[..split]).unwrap();
        fs::write(dir.join("sub").join("apps.toml"), &GOOD[split..]).unwrap();
        fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let set = load_dir(dir.to_str().unwrap()).unwrap();
        assert_eq!(set.apps.len(), 1);
        assert_eq!(set.machines.len(), 1);
        assert!(set.apps[0].file.ends_with("apps.toml"), "{}", set.apps[0].file);
        let _ = fs::remove_dir_all(&dir);
    }
}
