//! Load-time validation of definition sets (DESIGN.md §15).
//!
//! Validation is **loud and total**: every error names the file, the
//! table, and the key it concerns, and all errors are collected in one
//! pass — a contributor fixing a 500-definition directory gets the full
//! list, not a fix-one-rerun loop. `exacb measure --validate-only`
//! exposes this as a CI lint.

use super::model::DefSet;
use crate::workloads::known_binary;
use std::fmt;

/// One named validation failure.
#[derive(Debug, Clone, PartialEq)]
pub struct ValidationError {
    /// Source file (or `<builtin>`).
    pub file: String,
    /// Table context, e.g. `[[app]] 'climate-01'`.
    pub table: String,
    /// Offending key within the table (may be empty for table-level
    /// problems such as duplicate names).
    pub key: String,
    pub msg: String,
}

impl fmt::Display for ValidationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.key.is_empty() {
            write!(f, "{}: {}: {}", self.file, self.table, self.msg)
        } else {
            write!(f, "{}: {}: key '{}': {}", self.file, self.table, self.key, self.msg)
        }
    }
}

impl std::error::Error for ValidationError {}

pub(crate) fn verr(
    file: &str,
    table: &str,
    key: &str,
    msg: impl Into<String>,
) -> ValidationError {
    ValidationError {
        file: file.to_string(),
        table: table.to_string(),
        key: key.to_string(),
        msg: msg.into(),
    }
}

fn name_ok(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
}

/// Validate a parsed definition set; collects **all** errors.
pub fn validate(set: &DefSet) -> Result<(), Vec<ValidationError>> {
    let mut errs = Vec::new();

    if set.apps.is_empty() {
        errs.push(verr("<set>", "[[app]]", "", "definition set contains no apps"));
    }
    if set.machines.is_empty() {
        errs.push(verr("<set>", "[[machine]]", "", "definition set contains no machines"));
    }

    for (i, a) in set.apps.iter().enumerate() {
        let table = format!("[[app]] '{}'", a.name);
        let e = |key: &str, msg: String| verr(&a.file, &table, key, msg);
        if !name_ok(&a.name) {
            errs.push(e(
                "name",
                format!("'{}' is not a valid app name ([A-Za-z0-9._-]+)", a.name),
            ));
        }
        if let Some(prev) = set.apps[..i].iter().find(|p| p.name == a.name) {
            errs.push(e("", format!("duplicate app name (also defined in {})", prev.file)));
        }
        match set.engine(&a.engine) {
            None => errs.push(e(
                "engine",
                format!("references undefined engine '{}'", a.engine),
            )),
            Some(eng) => {
                let bin = eng.command.split_whitespace().next().unwrap_or("");
                if !known_binary(bin) {
                    errs.push(verr(
                        &eng.file,
                        &format!("[[engine]] '{}'", eng.name),
                        "command",
                        format!("'{bin}' is not an executable the harness knows"),
                    ));
                }
            }
        }
        if a.nodes < 1 {
            errs.push(e("nodes", "must be >= 1".into()));
        }
        if !(a.gflops_total > 0.0) {
            errs.push(e("gflops_total", "must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&a.serial_frac) {
            errs.push(e("serial_frac", "must be within [0, 1]".into()));
        }
        if !(0.0..=1.0).contains(&a.mem_bound) {
            errs.push(e("mem_bound", "must be within [0, 1]".into()));
        }
        if !(a.comm_mb >= 0.0) {
            errs.push(e("comm_mb", "must be >= 0".into()));
        }
        if a.steps < 1 {
            errs.push(e("steps", "must be >= 1".into()));
        }
        if !(0.0..=1.0).contains(&a.failure_rate) {
            errs.push(e("failure_rate", "must be within [0, 1]".into()));
        }
        if a.record_metrics.is_empty() {
            errs.push(e("record", "must list at least one metric".into()));
        } else if !a.record_metrics.contains(&a.primary_metric) {
            errs.push(e(
                "primary",
                format!("primary metric '{}' is not in 'record'", a.primary_metric),
            ));
        }
    }

    for (i, m) in set.machines.iter().enumerate() {
        let table = format!("[[machine]] '{}'", m.name);
        let e = |key: &str, msg: String| verr(&m.file, &table, key, msg);
        if !name_ok(&m.name) {
            errs.push(e(
                "name",
                format!("'{}' is not a valid machine name ([A-Za-z0-9._-]+)", m.name),
            ));
        }
        if let Some(prev) = set.machines[..i].iter().find(|p| p.name == m.name) {
            errs.push(e(
                "",
                format!("duplicate machine name (also defined in {})", prev.file),
            ));
        }
        if m.nodes < 1 {
            errs.push(e("nodes", "must be >= 1".into()));
        }
        if m.gpus_per_node < 1 {
            errs.push(e("gpus_per_node", "must be >= 1".into()));
        }
        if m.cores_per_node < 1 {
            errs.push(e("cores_per_node", "must be >= 1".into()));
        }
        if m.partitions.is_empty() {
            errs.push(e("partitions", "must list at least one partition".into()));
        }
        if !(m.stream_efficiency > 0.0 && m.stream_efficiency <= 1.0) {
            errs.push(e("stream_efficiency", "must be within (0, 1]".into()));
        }
        if !(0.0..1.0).contains(&m.noise_sigma) {
            errs.push(e("noise_sigma", "must be within [0, 1)".into()));
        }
        if !(m.perf_factor > 0.0) {
            errs.push(e("perf_factor", "must be > 0".into()));
        }
        if !(m.network.bw_gbs > 0.0) {
            errs.push(e("network.bw_gbs", "must be > 0".into()));
        }
        if !(m.network.latency_us >= 0.0) {
            errs.push(e("network.latency_us", "must be >= 0".into()));
        }
        if !(m.power.tdp_w > m.power.idle_w && m.power.idle_w >= 0.0) {
            errs.push(e("power.tdp_w", "need tdp_w > idle_w >= 0".into()));
        }
        if !(m.power.nominal_mhz >= m.power.min_mhz && m.power.min_mhz > 0.0) {
            errs.push(e("power.nominal_mhz", "need nominal_mhz >= min_mhz > 0".into()));
        }
    }

    for (i, eng) in set.engines.iter().enumerate() {
        let table = format!("[[engine]] '{}'", eng.name);
        if let Some(prev) = set.engines[..i].iter().find(|p| p.name == eng.name) {
            errs.push(verr(
                &eng.file,
                &table,
                "",
                format!("duplicate engine name (also defined in {})", prev.file),
            ));
        }
        if eng.command.trim().is_empty() {
            errs.push(verr(&eng.file, &table, "command", "must not be empty"));
        }
    }

    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs)
    }
}

#[cfg(test)]
mod tests {
    use super::super::builtin;
    use super::*;

    #[test]
    fn builtin_set_validates_clean() {
        validate(&builtin()).unwrap();
    }

    #[test]
    fn errors_name_file_table_and_key() {
        let mut set = builtin();
        set.apps[3].steps = 0;
        set.apps[3].failure_rate = 1.5;
        set.machines[1].stream_efficiency = 0.0;
        let errs = validate(&set).unwrap_err();
        assert_eq!(errs.len(), 3);
        let app_name = set.apps[3].name.clone();
        let shown: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(shown[0].contains("<builtin>"), "{}", shown[0]);
        assert!(shown[0].contains(&format!("[[app]] '{app_name}'")), "{}", shown[0]);
        assert!(shown[0].contains("key 'steps'"), "{}", shown[0]);
        assert!(shown[1].contains("key 'failure_rate'"), "{}", shown[1]);
        assert!(shown[2].contains("[[machine]] 'jupiter'"), "{}", shown[2]);
        assert!(shown[2].contains("stream_efficiency"), "{}", shown[2]);
    }

    #[test]
    fn unknown_engine_and_unknown_binary_flagged() {
        let mut set = builtin();
        set.apps[0].engine = "mystery".into();
        let errs = validate(&set).unwrap_err();
        assert!(errs.iter().any(|e| e.msg.contains("undefined engine 'mystery'")));

        let mut set = builtin();
        set.engines[0].command = "definitely-not-a-binary --x".into();
        let errs = validate(&set).unwrap_err();
        assert!(
            errs.iter().any(|e| e.key == "command" && e.msg.contains("definitely-not-a-binary")),
            "{errs:?}"
        );
    }

    #[test]
    fn duplicate_names_flagged_across_files() {
        let mut set = builtin();
        let mut dup = set.apps[0].clone();
        dup.file = "community/extra.toml".into();
        set.apps.push(dup);
        let errs = validate(&set).unwrap_err();
        let e = errs.iter().find(|e| e.msg.contains("duplicate app name")).unwrap();
        assert_eq!(e.file, "community/extra.toml");
        assert!(e.msg.contains("<builtin>"), "{e}");
    }

    #[test]
    fn empty_set_is_invalid() {
        let errs = validate(&DefSet::default()).unwrap_err();
        assert_eq!(errs.len(), 2);
    }

    #[test]
    fn metric_contract_enforced() {
        let mut set = builtin();
        set.apps[0].primary_metric = "latency".into();
        let errs = validate(&set).unwrap_err();
        assert!(errs.iter().any(|e| e.key == "primary"), "{errs:?}");
        let mut set = builtin();
        set.apps[0].record_metrics.clear();
        let errs = validate(&set).unwrap_err();
        assert!(errs.iter().any(|e| e.key == "record"), "{errs:?}");
    }
}
