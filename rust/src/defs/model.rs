//! Typed definition model (DESIGN.md §15).
//!
//! The parsed, validated form of a `*.toml` definition tree: apps
//! (command parameter space + metric contract + planted-behavior
//! profile), machines (partitions, node shape, power/stage fingerprint),
//! and engines (labelled commands). Each definition remembers the file
//! it came from for error naming; equality deliberately ignores that
//! provenance, so a definition set rendered from the built-ins compares
//! equal to the same set loaded back from disk.

use crate::cluster::{GpuGen, NetworkLink, PowerModel};
use crate::workloads::portfolio::Maturity;

/// Provenance marker for definitions constructed in code.
pub const BUILTIN_FILE: &str = "<builtin>";

/// One benchmark application definition (`[[app]]`).
#[derive(Debug, Clone)]
pub struct AppDef {
    pub name: String,
    pub domain: String,
    pub maturity: Maturity,
    /// Name of the engine (`[[engine]]`) whose command runs this app.
    pub engine: String,
    /// Default node count of the standard use case.
    pub nodes: u64,
    // -- parameter space (`[app.parameters]`) --
    pub gflops_total: f64,
    pub serial_frac: f64,
    pub mem_bound: f64,
    pub comm_mb: f64,
    pub steps: u64,
    pub weak: bool,
    // -- planted-behavior profile (`[app.behavior]`) --
    pub failure_rate: f64,
    // -- metric contract (`[app.metrics]`) --
    pub primary_metric: String,
    pub record_metrics: Vec<String>,
    /// Source file (error naming only; ignored by equality).
    pub file: String,
}

impl PartialEq for AppDef {
    fn eq(&self, other: &AppDef) -> bool {
        self.name == other.name
            && self.domain == other.domain
            && self.maturity == other.maturity
            && self.engine == other.engine
            && self.nodes == other.nodes
            && self.gflops_total == other.gflops_total
            && self.serial_frac == other.serial_frac
            && self.mem_bound == other.mem_bound
            && self.comm_mb == other.comm_mb
            && self.steps == other.steps
            && self.weak == other.weak
            && self.failure_rate == other.failure_rate
            && self.primary_metric == other.primary_metric
            && self.record_metrics == other.record_metrics
    }
}

/// One machine definition (`[[machine]]`).
#[derive(Debug, Clone)]
pub struct MachineDef {
    pub name: String,
    pub version: String,
    pub gpu: GpuGen,
    pub nodes: u64,
    pub gpus_per_node: u64,
    pub cores_per_node: u64,
    /// Batch partitions (queues) this system exposes.
    pub partitions: Vec<String>,
    /// Network fingerprint (`[machine.network]` or a preset name).
    pub network: NetworkLink,
    /// Power fingerprint (`[machine.power]` or a preset name).
    pub power: PowerModel,
    pub stream_efficiency: f64,
    pub noise_sigma: f64,
    pub perf_factor: f64,
    /// Source file (error naming only; ignored by equality).
    pub file: String,
}

impl PartialEq for MachineDef {
    fn eq(&self, other: &MachineDef) -> bool {
        self.name == other.name
            && self.version == other.version
            && self.gpu == other.gpu
            && self.nodes == other.nodes
            && self.gpus_per_node == other.gpus_per_node
            && self.cores_per_node == other.cores_per_node
            && self.partitions == other.partitions
            && self.network == other.network
            && self.power == other.power
            && self.stream_efficiency == other.stream_efficiency
            && self.noise_sigma == other.noise_sigma
            && self.perf_factor == other.perf_factor
    }
}

/// One engine definition (`[[engine]]`): a labelled command.
#[derive(Debug, Clone)]
pub struct EngineDef {
    pub name: String,
    /// Binary (first word) must pass `workloads::known_binary`.
    pub command: String,
    pub description: String,
    /// Source file (error naming only; ignored by equality).
    pub file: String,
}

impl PartialEq for EngineDef {
    fn eq(&self, other: &EngineDef) -> bool {
        self.name == other.name
            && self.command == other.command
            && self.description == other.description
    }
}

/// A complete definition set, in file-then-declaration order.
///
/// Order is semantic, not cosmetic: app order drives the round-robin
/// machine assignment and the seeded daily shuffle of the campaign work
/// queue, so the shipped `benchmarks/` set lists apps in exactly the
/// built-in portfolio order to replay it byte-identically.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DefSet {
    pub apps: Vec<AppDef>,
    pub machines: Vec<MachineDef>,
    pub engines: Vec<EngineDef>,
}

impl DefSet {
    pub fn app(&self, name: &str) -> Option<&AppDef> {
        self.apps.iter().find(|a| a.name == name)
    }

    pub fn machine(&self, name: &str) -> Option<&MachineDef> {
        self.machines.iter().find(|m| m.name == name)
    }

    pub fn engine(&self, name: &str) -> Option<&EngineDef> {
        self.engines.iter().find(|e| e.name == name)
    }

    /// Machines exposing a given partition (queue) name.
    pub fn machines_with_partition(&self, queue: &str) -> Vec<&MachineDef> {
        self.machines
            .iter()
            .filter(|m| m.partitions.iter().any(|p| p == queue))
            .collect()
    }
}
