//! BYOB definition layer: benchmarks, machines, and planted behaviors
//! as data, not code (DESIGN.md §15).
//!
//! Everything a collection campaign needs — which apps to run, on which
//! machines, with which planted-behavior profile — can be expressed as a
//! directory of `*.toml` files and loaded at runtime:
//!
//! * [`model`] — the typed definition model ([`AppDef`], [`MachineDef`],
//!   [`EngineDef`], [`DefSet`]).
//! * [`load`] — `*.toml` tree discovery + parsing via
//!   [`crate::util::tomlite`].
//! * [`validate`] — loud semantic validation; every error names file,
//!   table, and key.
//!
//! The built-in 72-app JUREAP portfolio and the four standard machines
//! are themselves re-expressed as the first shipped definition set
//! ([`builtin`] / [`render`], checked byte-identical to the code path by
//! `tests/integration_defs.rs`): the code constructors are now just one
//! producer of the same [`DefSet`] the loader yields. [`run_measure`]
//! drives a loaded set through the existing concurrent campaign core —
//! this is what `exacb measure -d <dir>` calls.

pub mod load;
pub mod model;
pub mod validate;

pub use load::{load_dir, parse_files, DefsError};
pub use model::{AppDef, DefSet, EngineDef, MachineDef, BUILTIN_FILE};
pub use validate::{validate, ValidationError};

use crate::cluster::{Cluster, EventLog, GpuGen, Machine};
use crate::coordinator::{
    onboard_multi, run_campaign_concurrent_with, CollectionSummary, PipelineTask, World,
};
use crate::workloads::portfolio::{jureap, PortfolioApp};

/// The built-in JUREAP-like collection as a definition set: the 72-app
/// portfolio, the four standard machines, and the `simapp` engine.
pub fn builtin() -> DefSet {
    let apps = jureap()
        .iter()
        .map(|a| AppDef {
            name: a.name.clone(),
            domain: a.domain.clone(),
            maturity: a.maturity,
            engine: "simapp".to_string(),
            nodes: a.nodes,
            gflops_total: a.model.gflops_total,
            serial_frac: a.model.serial_frac,
            mem_bound: a.model.mem_bound,
            comm_mb: a.model.comm_mb,
            steps: a.model.steps,
            weak: a.model.weak,
            failure_rate: a.failure_rate,
            primary_metric: "tts".to_string(),
            record_metrics: vec!["tts".to_string(), "gflops_rate".to_string()],
            file: BUILTIN_FILE.to_string(),
        })
        .collect();
    let machines = crate::cluster::standard_machines()
        .iter()
        .map(|m| MachineDef {
            name: m.name.clone(),
            version: m.version.clone(),
            gpu: m.gpu_gen,
            nodes: m.nodes,
            gpus_per_node: m.gpus_per_node,
            cores_per_node: m.cores_per_node,
            partitions: m.queues.clone(),
            network: m.network.clone(),
            power: m.power.clone(),
            stream_efficiency: m.stream_efficiency,
            noise_sigma: m.noise_sigma,
            perf_factor: m.perf_factor,
            file: BUILTIN_FILE.to_string(),
        })
        .collect();
    let engines = vec![EngineDef {
        name: "simapp".to_string(),
        command: "simapp".to_string(),
        description: "parameterised scalable application (workloads::scalable)".to_string(),
        file: BUILTIN_FILE.to_string(),
    }];
    DefSet {
        apps,
        machines,
        engines,
    }
}

fn toml_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render an `f64` so [`crate::util::tomlite`] parses it back to the
/// same bits: `{:?}` emits the shortest round-tripping decimal and
/// always keeps a `.` or exponent, so the token stays a float.
fn toml_f64(v: f64) -> String {
    format!("{v:?}")
}

fn gpu_slug(g: GpuGen) -> &'static str {
    match g {
        GpuGen::Ampere => "ampere",
        GpuGen::Hopper => "hopper",
        GpuGen::GraceHopper => "gh200",
    }
}

fn str_list(items: &[String]) -> String {
    let quoted: Vec<String> = items.iter().map(|s| toml_str(s)).collect();
    format!("[{}]", quoted.join(", "))
}

/// Render a definition set as `(file name, contents)` pairs — the exact
/// shipped `benchmarks/` layout. `parse_files(&render(set))` must
/// reproduce `set` bit-for-bit (property-tested), which is how the
/// shipped definition directory was generated and how it is proven to
/// replay the built-in portfolio.
pub fn render(set: &DefSet) -> Vec<(String, String)> {
    let mut engines = String::from(
        "# Engines: labelled harness commands (generated from the built-in set).\n",
    );
    for e in &set.engines {
        engines.push_str(&format!(
            "\n[[engine]]\nname = {}\ncommand = {}\ndescription = {}\n",
            toml_str(&e.name),
            toml_str(&e.command),
            toml_str(&e.description),
        ));
    }

    let mut apps = String::from(
        "# The JUREAP-like 72-app portfolio as data. App order is semantic:\n\
         # it drives machine assignment and the seeded daily shuffle, so\n\
         # this file lists apps in exactly the built-in portfolio order.\n",
    );
    for a in &set.apps {
        apps.push_str(&format!(
            "\n[[app]]\nname = {name}\ndomain = {domain}\nmaturity = {mat}\n\
             engine = {engine}\nnodes = {nodes}\n\n\
             [app.parameters]\ngflops_total = {gf}\nserial_frac = {sf}\n\
             mem_bound = {mb}\ncomm_mb = {cm}\nsteps = {steps}\nweak = {weak}\n\n\
             [app.behavior]\nfailure_rate = {fr}\n\n\
             [app.metrics]\nprimary = {prim}\nrecord = {rec}\n",
            name = toml_str(&a.name),
            domain = toml_str(&a.domain),
            mat = toml_str(a.maturity.name()),
            engine = toml_str(&a.engine),
            nodes = a.nodes,
            gf = toml_f64(a.gflops_total),
            sf = toml_f64(a.serial_frac),
            mb = toml_f64(a.mem_bound),
            cm = toml_f64(a.comm_mb),
            steps = a.steps,
            weak = a.weak,
            fr = toml_f64(a.failure_rate),
            prim = toml_str(&a.primary_metric),
            rec = str_list(&a.record_metrics),
        ));
    }

    let mut machines = String::from(
        "# The four standard JSC-like systems with full network and power\n\
         # fingerprints (presets like network = \"ndr400\" also work).\n",
    );
    for m in &set.machines {
        machines.push_str(&format!(
            "\n[[machine]]\nname = {name}\nversion = {version}\ngpu = {gpu}\n\
             nodes = {nodes}\ngpus_per_node = {gpn}\ncores_per_node = {cpn}\n\
             partitions = {parts}\nstream_efficiency = {se}\nnoise_sigma = {ns}\n\
             perf_factor = {pf}\n\n\
             [machine.network]\nname = {nname}\nlatency_us = {lat}\nbw_gbs = {bw}\n\
             rndv_handshake_us = {hs}\neager_bw_fraction = {ebf}\n\
             eager_per_kb_us = {ekb}\ndefault_rndv_thresh = {thresh}\n\n\
             [machine.power]\nidle_w = {idle}\ntdp_w = {tdp}\nnominal_mhz = {nom}\n\
             min_mhz = {min}\nsensor_noise_w = {snw}\n",
            name = toml_str(&m.name),
            version = toml_str(&m.version),
            gpu = toml_str(gpu_slug(m.gpu)),
            nodes = m.nodes,
            gpn = m.gpus_per_node,
            cpn = m.cores_per_node,
            parts = str_list(&m.partitions),
            se = toml_f64(m.stream_efficiency),
            ns = toml_f64(m.noise_sigma),
            pf = toml_f64(m.perf_factor),
            nname = toml_str(&m.network.name),
            lat = toml_f64(m.network.latency_us),
            bw = toml_f64(m.network.bw_gbs),
            hs = toml_f64(m.network.rndv_handshake_us),
            ebf = toml_f64(m.network.eager_bw_fraction),
            ekb = toml_f64(m.network.eager_per_kb_us),
            thresh = m.network.default_rndv_thresh,
            idle = toml_f64(m.power.idle_w),
            tdp = toml_f64(m.power.tdp_w),
            nom = toml_f64(m.power.nominal_mhz),
            min = toml_f64(m.power.min_mhz),
            snw = toml_f64(m.power.sensor_noise_w),
        ));
    }

    vec![
        ("engines.toml".to_string(), engines),
        ("jureap.toml".to_string(), apps),
        ("machines.toml".to_string(), machines),
    ]
}

/// The definition set as campaign apps, in definition order.
pub fn to_portfolio(set: &DefSet) -> Vec<PortfolioApp> {
    set.apps.iter().map(PortfolioApp::from_def).collect()
}

/// The definition set as a simulated computing centre.
pub fn to_cluster(set: &DefSet) -> Cluster {
    Cluster {
        machines: set.machines.iter().map(Machine::from_def).collect(),
        events: EventLog::new(),
    }
}

/// How to run a definition set as a campaign (`exacb measure` flags).
#[derive(Debug, Clone)]
pub struct MeasurePlan {
    /// Limit to the first N apps (0 = all).
    pub apps: usize,
    /// Simulated campaign days per sweep.
    pub days: i64,
    /// Machines to run on; empty = every machine exposing `queue`.
    pub machines: Vec<String>,
    /// Batch partition campaigns submit to.
    pub queue: String,
    pub seed: u64,
    /// Enable the execution cache (warm sweeps replay from it).
    pub cache: bool,
    /// Number of campaign sweeps over the same days (>1 exercises warm
    /// replay).
    pub sweeps: u32,
}

impl Default for MeasurePlan {
    fn default() -> Self {
        MeasurePlan {
            apps: 0,
            days: 3,
            machines: Vec::new(),
            queue: "all".to_string(),
            seed: 20260101,
            cache: true,
            sweeps: 1,
        }
    }
}

/// Run a validated definition set through the concurrent campaign core
/// with a pluggable event loop (the differential tests drive the same
/// set through `drive` and `drive_reference`).
pub fn run_measure_with(
    set: &DefSet,
    plan: &MeasurePlan,
    drive: fn(&mut World, Vec<PipelineTask>) -> Vec<u64>,
) -> Result<(World, Vec<CollectionSummary>), String> {
    let mut apps = to_portfolio(set);
    if plan.apps > 0 && plan.apps < apps.len() {
        apps.truncate(plan.apps);
    }
    let machine_names: Vec<String> = if plan.machines.is_empty() {
        set.machines_with_partition(&plan.queue)
            .iter()
            .map(|m| m.name.clone())
            .collect()
    } else {
        for name in &plan.machines {
            let Some(m) = set.machine(name) else {
                return Err(format!("unknown machine '{name}' in definition set"));
            };
            if !m.partitions.iter().any(|p| p == &plan.queue) {
                return Err(format!(
                    "machine '{name}' does not expose partition '{}'",
                    plan.queue
                ));
            }
        }
        plan.machines.clone()
    };
    if machine_names.is_empty() {
        return Err(format!("no machine exposes partition '{}'", plan.queue));
    }
    let mut world = World::with_cluster(to_cluster(set), plan.seed);
    if plan.cache {
        world.enable_cache();
    }
    let machine_refs: Vec<&str> = machine_names.iter().map(String::as_str).collect();
    onboard_multi(&mut world, &apps, &machine_refs, &plan.queue);
    let mut summaries = Vec::new();
    for _ in 0..plan.sweeps.max(1) {
        summaries.push(run_campaign_concurrent_with(
            &mut world,
            &apps,
            &machine_refs,
            plan.days,
            drive,
        ));
    }
    Ok((world, summaries))
}

/// [`run_measure_with`] under the production event loop.
pub fn run_measure(
    set: &DefSet,
    plan: &MeasurePlan,
) -> Result<(World, Vec<CollectionSummary>), String> {
    run_measure_with(set, plan, crate::coordinator::event_loop::drive)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_matches_code_constructors() {
        let set = builtin();
        assert_eq!(set.apps.len(), 72);
        assert_eq!(set.machines.len(), 4);
        assert_eq!(to_portfolio(&set), jureap());
        let cluster = to_cluster(&set);
        assert_eq!(cluster.machines, Cluster::standard().machines);
    }

    #[test]
    fn render_round_trips_bit_exact() {
        let set = builtin();
        let rendered = render(&set);
        assert_eq!(rendered.len(), 3);
        let loaded = parse_files(&rendered).expect("rendered builtin must parse clean");
        // f64 fields compare by == (bit-exact for non-NaN), and PartialEq
        // ignores provenance — this is the whole round-trip contract
        assert_eq!(loaded, set);
    }

    #[test]
    fn rendered_floats_never_use_uppercase_or_lose_the_point() {
        // guard the render contract toml_f64 relies on
        for (_, text) in render(&builtin()) {
            for line in text.lines() {
                assert!(!line.contains('E'), "uppercase exponent in {line}");
            }
        }
    }

    #[test]
    fn measure_plan_resolves_machines_by_partition() {
        let set = builtin();
        let plan = MeasurePlan {
            apps: 2,
            days: 1,
            queue: "booster".to_string(),
            ..MeasurePlan::default()
        };
        let (world, summaries) = run_measure(&set, &plan).unwrap();
        // jupiter + juwels-booster expose "booster"
        assert_eq!(summaries.len(), 1);
        assert_eq!(summaries[0].apps, 2);
        assert_eq!(summaries[0].pipelines_run, 2);
        assert!(world.repos.len() == 2);
    }

    #[test]
    fn measure_plan_rejects_bad_machines_loudly() {
        let set = builtin();
        let mut plan = MeasurePlan {
            apps: 1,
            days: 1,
            ..MeasurePlan::default()
        };
        plan.machines = vec!["frontier".to_string()];
        let err = run_measure(&set, &plan).unwrap_err();
        assert!(err.contains("unknown machine 'frontier'"), "{err}");
        plan.machines = vec!["juwels-booster".to_string()];
        plan.queue = "all".to_string();
        let err = run_measure(&set, &plan).unwrap_err();
        assert!(err.contains("does not expose partition 'all'"), "{err}");
        plan.machines = Vec::new();
        plan.queue = "no-such-queue".to_string();
        let err = run_measure(&set, &plan).unwrap_err();
        assert!(err.contains("no machine exposes partition"), "{err}");
    }
}
