//! Measurement-scope detection (the black vertical bars of Fig. 8).
//!
//! "The measurement scope excludes start-up and wind-down phases, as they
//! are in many cases not representative of the overall application
//! profile — of course, this systematically underestimates the reported
//! energy. The semi-automatic approach automatically places the vertical
//! guide, but allows for human verification and adaption." (§VI-B)
//!
//! Detection: a sample belongs to the steady phase when it exceeds
//! idle + `threshold` × (steady − idle); the scope is the first/last such
//! sample, shrunk by a guard band. Manual adjustment shifts the bars.

use super::trace::{trapezoid, PowerTrace};

/// A detected measurement scope (sample indices, inclusive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scope {
    pub start: usize,
    pub end: usize,
}

impl Scope {
    pub fn len(&self) -> usize {
        self.end.saturating_sub(self.start)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Manual adaption (the "human verification" step): shift both bars.
    pub fn adjusted(&self, dstart: i64, dend: i64, max_len: usize) -> Scope {
        let start = (self.start as i64 + dstart).max(0) as usize;
        let end = ((self.end as i64 + dend).max(0) as usize).min(max_len.saturating_sub(1));
        Scope {
            start: start.min(end),
            end,
        }
    }
}

/// Automatically place the measurement-scope bars on a trace.
///
/// `threshold` is the fraction of the idle→peak swing a sample must
/// exceed to count as "in the run" (default 0.5 works for the standard
/// phase shapes). `peak` is the p95 of the samples, not the single
/// maximum: one sensor spike used to inflate the threshold far above
/// steady power, shrinking the scope to the spike's neighbourhood — or
/// destroying it entirely when only the spike cleared the cut.
pub fn detect_scope(trace: &PowerTrace, idle_w: f64, threshold: f64) -> Option<Scope> {
    if trace.samples.is_empty() {
        return None;
    }
    let peak = crate::util::stats::percentile(&trace.samples, 95.0);
    if peak <= idle_w {
        return None;
    }
    let cut = idle_w + threshold.clamp(0.05, 0.95) * (peak - idle_w);
    let first = trace.samples.iter().position(|&p| p > cut)?;
    let last = trace.samples.iter().rposition(|&p| p > cut)?;
    if last <= first {
        return None;
    }
    // guard band: move inside the ramps by ~2 samples each side
    let guard = 2usize;
    let start = (first + guard).min(last);
    let end = last.saturating_sub(guard).max(start);
    if end <= start {
        return None;
    }
    Some(Scope { start, end })
}

/// Energy within the scope [J] (trapezoidal integration).
pub fn integrate_energy(trace: &PowerTrace, scope: Scope) -> f64 {
    trapezoid(&trace.samples, trace.dt_s, scope.start, scope.end)
}

/// Average power within the scope [W].
pub fn average_power(trace: &PowerTrace, scope: Scope) -> f64 {
    if scope.is_empty() {
        return 0.0;
    }
    integrate_energy(trace, scope) / (scope.len() as f64 * trace.dt_s)
}

#[cfg(test)]
mod tests {
    use super::super::trace::{sample_trace, PowerTrace};
    use super::*;
    use crate::cluster::PowerModel;
    use crate::util::prng::Prng;
    use crate::workloads::AppProfile;

    fn mk() -> (PowerTrace, PowerModel) {
        let p = PowerModel::a100();
        let mut rng = Prng::new(3);
        let t = sample_trace(
            0,
            &p,
            AppProfile {
                utilization: 0.9,
                mem_bound: 0.3,
            },
            p.nominal_mhz,
            120.0,
            &mut rng,
        );
        (t, p)
    }

    #[test]
    fn scope_excludes_ramps() {
        let (t, p) = mk();
        let scope = detect_scope(&t, p.idle_w, 0.5).unwrap();
        // scope starts after the 5 s idle margin and some ramp
        assert!(scope.start >= 5, "start={}", scope.start);
        assert!(scope.end <= t.samples.len() - 5, "end={}", scope.end);
        // scoped samples are all near steady power
        let steady = p.power_w(p.nominal_mhz, 0.9);
        for &s in &t.samples[scope.start..=scope.end] {
            assert!(s > 0.7 * steady, "{s} vs {steady}");
        }
    }

    #[test]
    fn scoped_energy_underestimates_total() {
        // "this systematically underestimates the reported energy"
        let (t, p) = mk();
        let scope = detect_scope(&t, p.idle_w, 0.5).unwrap();
        let scoped = integrate_energy(&t, scope);
        let total = t.total_energy_j();
        assert!(scoped < total);
        assert!(scoped > 0.75 * total, "scope too aggressive: {scoped} vs {total}");
    }

    #[test]
    fn manual_adjustment_moves_bars() {
        let (t, p) = mk();
        let scope = detect_scope(&t, p.idle_w, 0.5).unwrap();
        let wider = scope.adjusted(-3, 3, t.samples.len());
        assert_eq!(wider.start, scope.start - 3);
        assert_eq!(wider.end, scope.end + 3);
        assert!(integrate_energy(&t, wider) > integrate_energy(&t, scope));
        // clamped at trace edges
        let clamped = scope.adjusted(-1000, 1000, t.samples.len());
        assert_eq!(clamped.start, 0);
        assert_eq!(clamped.end, t.samples.len() - 1);
    }

    /// Regression: a single sensor spike must not set the detection
    /// threshold. With the max-based cut a 10× spike pushed the bar above
    /// steady power, so only the spike itself cleared it and the scope
    /// collapsed onto (or vanished around) one sample.
    #[test]
    fn sensor_spike_does_not_destroy_the_scope() {
        let (mut t, p) = mk();
        let clean = detect_scope(&t, p.idle_w, 0.5).unwrap();
        // plant a one-sample telemetry glitch mid-run
        let mid = t.samples.len() / 2;
        t.samples[mid] = 10.0 * p.power_w(p.nominal_mhz, 0.9);
        let spiked = detect_scope(&t, p.idle_w, 0.5).expect("scope must survive the spike");
        // the scope still covers the bulk of the run, not just the spike
        assert!(spiked.len() > clean.len() / 2, "{spiked:?} vs clean {clean:?}");
        assert!(spiked.start <= clean.start + 2, "{spiked:?} vs {clean:?}");
        assert!(spiked.end + 2 >= clean.end, "{spiked:?} vs {clean:?}");
        // empty traces stay scope-less
        let empty = PowerTrace { gpu: 0, dt_s: 1.0, samples: vec![] };
        assert!(detect_scope(&empty, p.idle_w, 0.5).is_none());
    }

    #[test]
    fn flat_idle_trace_has_no_scope() {
        let t = PowerTrace {
            gpu: 0,
            dt_s: 1.0,
            samples: vec![55.0; 50],
        };
        assert!(detect_scope(&t, 55.0, 0.5).is_none());
    }

    #[test]
    fn average_power_is_near_steady() {
        let (t, p) = mk();
        let scope = detect_scope(&t, p.idle_w, 0.5).unwrap();
        let avg = average_power(&t, scope);
        let steady = p.power_w(p.nominal_mhz, 0.9);
        assert!((avg - steady).abs() < 0.1 * steady, "{avg} vs {steady}");
    }
}
