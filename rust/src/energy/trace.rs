//! Per-GPU power traces (the raw material of Fig. 8).
//!
//! A run's power profile has three phases, as the paper describes for
//! measurement-scope calibration: start-up (ramp from idle), steady
//! execution at the workload's utilisation, and wind-down back to idle.
//! Sensor noise rides on top. Sampling is 1 Hz, like typical node power
//! telemetry.

use crate::cluster::PowerModel;
use crate::util::prng::Prng;
use crate::workloads::AppProfile;

/// One GPU's sampled power series.
#[derive(Debug, Clone)]
pub struct PowerTrace {
    pub gpu: usize,
    /// Sample period [s].
    pub dt_s: f64,
    /// Power samples [W].
    pub samples: Vec<f64>,
}

impl PowerTrace {
    /// Trapezoidal energy over the full trace [J].
    pub fn total_energy_j(&self) -> f64 {
        trapezoid(&self.samples, self.dt_s, 0, self.samples.len().saturating_sub(1))
    }

    /// Trapezoidal energy between two sample indices [J].
    pub fn energy_between_j(&self, start: usize, end: usize) -> f64 {
        trapezoid(&self.samples, self.dt_s, start, end)
    }
}

pub(crate) fn trapezoid(samples: &[f64], dt: f64, start: usize, end: usize) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    // clamp uniformly: any out-of-range `end` means "to the last sample".
    // The old guard returned 0.0 for `end >= len + 1` while clamping
    // `end == len`, so a caller asking for the tail energy past the end
    // silently lost the whole integral instead of the overhang.
    let end = end.min(samples.len() - 1);
    if end <= start {
        return 0.0;
    }
    let mut e = 0.0;
    for i in start..end {
        e += 0.5 * (samples[i] + samples[i + 1]) * dt;
    }
    e
}

/// Fractions of the runtime spent ramping up / down.
const RAMP_UP_FRAC: f64 = 0.06;
const RAMP_DOWN_FRAC: f64 = 0.05;
/// Minimum ramp lengths [s] (short jobs still show the phases).
const MIN_RAMP_S: f64 = 3.0;

/// Sample a power trace for one GPU of a run.
///
/// `runtime_s` is the application runtime; the trace covers it plus a
/// little idle margin on both ends (what a telemetry window records).
pub fn sample_trace(
    gpu: usize,
    power: &PowerModel,
    profile: AppProfile,
    freq_mhz: f64,
    runtime_s: f64,
    rng: &mut Prng,
) -> PowerTrace {
    let runtime_s = runtime_s.max(0.5);
    // adaptive sample period: ~240 samples over the run, capped at 1 Hz
    // (telemetry rate) — short jobs still get a resolvable trace
    let dt = (runtime_s / 240.0).clamp(0.05, 1.0);
    let idle_margin_s = 10.0 * dt;
    // ramps never consume more than half the run
    let ramp_up = (runtime_s * RAMP_UP_FRAC).max(MIN_RAMP_S.min(runtime_s * 0.25));
    let ramp_down = (runtime_s * RAMP_DOWN_FRAC).max(MIN_RAMP_S.min(runtime_s * 0.2));
    let total = idle_margin_s + runtime_s + idle_margin_s;
    let n = (total / dt).ceil() as usize + 1;
    let steady_power = power.power_w(freq_mhz, profile.utilization);
    let idle = power.idle_w;
    let mut samples = Vec::with_capacity(n);
    for i in 0..n {
        let t = i as f64 * dt;
        // position within the run
        let in_run = t - idle_margin_s;
        let base = if in_run < 0.0 || in_run > runtime_s {
            idle
        } else if in_run < ramp_up {
            idle + (steady_power - idle) * (in_run / ramp_up)
        } else if in_run > runtime_s - ramp_down {
            idle + (steady_power - idle) * ((runtime_s - in_run) / ramp_down).max(0.0)
        } else {
            // small utilisation wobble during steady state
            steady_power * (1.0 + 0.01 * (t * 0.7).sin())
        };
        samples.push((base + rng.normal(0.0, power.sensor_noise_w)).max(0.0));
    }
    PowerTrace {
        gpu,
        dt_s: dt,
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::PowerModel;

    fn mk_trace(runtime: f64) -> PowerTrace {
        let p = PowerModel::a100();
        let mut rng = Prng::new(1);
        sample_trace(
            0,
            &p,
            AppProfile {
                utilization: 0.9,
                mem_bound: 0.3,
            },
            p.nominal_mhz,
            runtime,
            &mut rng,
        )
    }

    #[test]
    fn trace_has_three_phases() {
        let t = mk_trace(100.0);
        let p = PowerModel::a100();
        // first and last samples near idle
        assert!((t.samples[0] - p.idle_w).abs() < 20.0);
        assert!((t.samples.last().unwrap() - p.idle_w).abs() < 20.0);
        // middle near steady power
        let mid = t.samples[t.samples.len() / 2];
        let steady = p.power_w(p.nominal_mhz, 0.9);
        assert!((mid - steady).abs() < 0.05 * steady, "{mid} vs {steady}");
    }

    #[test]
    fn energy_scales_with_runtime() {
        let e_short = mk_trace(50.0).total_energy_j();
        let e_long = mk_trace(200.0).total_energy_j();
        assert!(e_long > 3.0 * e_short);
    }

    #[test]
    fn trapezoid_of_constant_is_exact() {
        let samples = vec![100.0; 11];
        assert!((trapezoid(&samples, 1.0, 0, 10) - 1000.0).abs() < 1e-9);
        assert_eq!(trapezoid(&samples, 1.0, 5, 5), 0.0);
        assert_eq!(trapezoid(&[], 1.0, 0, 10), 0.0);
    }

    /// Regression: an `end` past the last sample clamps to it instead of
    /// silently dropping the whole tail energy. `end == len` already
    /// clamped; `end >= len + 1` used to return 0.0.
    #[test]
    fn trapezoid_clamps_out_of_range_end_uniformly() {
        let samples = vec![100.0; 11];
        let full = trapezoid(&samples, 1.0, 0, 10);
        assert_eq!(trapezoid(&samples, 1.0, 0, 11), full);
        assert_eq!(trapezoid(&samples, 1.0, 0, 12), full);
        assert_eq!(trapezoid(&samples, 1.0, 0, usize::MAX), full);
        // the same contract through the public tail-energy entry point
        let t = mk_trace(60.0);
        let n = t.samples.len();
        let tail = t.energy_between_j(n / 2, n - 1);
        assert!(tail > 0.0);
        assert_eq!(t.energy_between_j(n / 2, n + 3), tail);
        // a start past the end is still empty, never negative
        assert_eq!(t.energy_between_j(n + 1, n + 5), 0.0);
    }

    #[test]
    fn lower_frequency_lowers_power() {
        let p = PowerModel::gh200();
        let mut rng = Prng::new(2);
        let prof = AppProfile {
            utilization: 0.9,
            mem_bound: 0.5,
        };
        let hi = sample_trace(0, &p, prof, p.nominal_mhz, 60.0, &mut rng);
        let lo = sample_trace(0, &p, prof, p.nominal_mhz * 0.6, 60.0, &mut rng);
        let mid = |t: &PowerTrace| t.samples[t.samples.len() / 2];
        assert!(mid(&lo) < 0.6 * mid(&hi), "{} vs {}", mid(&lo), mid(&hi));
    }
}
