//! The jpwr-like energy-aware launcher (paper §VI-B).
//!
//! "This support is typically enabled without modifying the benchmarks
//! themselves ... The JUBE platform configuration selects jpwr as the
//! launcher" — here: the executor calls [`wrap_with_jpwr`] around an
//! already-produced [`AppOutput`] when the platform config selects the
//! `jpwr` launcher. The wrapper samples one power trace per GPU of the
//! first node, detects the measurement scope, integrates energy, and
//! enriches the protocol metrics — the benchmark's own output is
//! untouched.

use super::scope::{average_power, detect_scope, integrate_energy, Scope};
use super::trace::{sample_trace, PowerTrace};
use crate::cluster::Machine;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::workloads::AppOutput;

/// The energy measurement attached to a run.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub traces: Vec<PowerTrace>,
    pub scopes: Vec<Scope>,
    /// Scoped energy-to-solution, all sampled GPUs × all nodes [J].
    pub energy_j: f64,
    pub avg_power_w: f64,
}

/// Wrap an application result with jpwr-style energy measurement.
///
/// Samples `gpus_per_node` traces (the four GPU series of Fig. 8),
/// detects per-trace scopes, integrates, and extrapolates node energy ×
/// `nodes`. Returns the enriched output plus the report (for plotting).
pub fn wrap_with_jpwr(
    mut output: AppOutput,
    machine: &Machine,
    nodes: u64,
    freq_mhz: f64,
    rng: &mut Prng,
) -> (AppOutput, EnergyReport) {
    let gpus = machine.gpus_per_node as usize;
    let mut traces = Vec::with_capacity(gpus);
    let mut scopes = Vec::with_capacity(gpus);
    let mut energy = 0.0;
    let mut power_sum = 0.0;
    for gpu in 0..gpus {
        let trace = sample_trace(
            gpu,
            &machine.power,
            output.profile,
            freq_mhz,
            output.runtime_s,
            rng,
        );
        let scope = detect_scope(&trace, machine.power.idle_w, 0.5).unwrap_or(Scope {
            start: 0,
            end: trace.samples.len().saturating_sub(1),
        });
        energy += integrate_energy(&trace, scope);
        power_sum += average_power(&trace, scope);
        traces.push(trace);
        scopes.push(scope);
    }
    let node_energy = energy; // one node's GPUs
    let total_energy = node_energy * nodes as f64;
    // a CPU-only machine (gpus_per_node == 0) samples no traces: the
    // per-GPU averages are undefined, not 0/0 — dividing anyway used to
    // poison the report JSON with NaN
    let avg_power = if gpus > 0 { power_sum / gpus as f64 } else { 0.0 };

    output.metrics.insert("energy_j", total_energy);
    output.metrics.insert("node_energy_j", node_energy);
    // energy-delay product [J·s]: the tracking-side figure of merit for
    // frequency studies (lower is better at equal work) — recorded as a
    // plain metric so `tracking::history` can gate on it like `energy_j`
    output.metrics.insert("edp", total_energy * output.runtime_s);
    output.metrics.insert("freq_mhz", freq_mhz);
    if gpus > 0 {
        output.metrics.insert("avg_power_w", avg_power);
        output
            .metrics
            .insert("energy_per_gpu_j", node_energy / gpus as f64);
    }
    output
        .metrics
        .insert("launcher", Json::Str("jpwr".into()));

    (
        output,
        EnergyReport {
            traces,
            scopes,
            energy_j: total_energy,
            avg_power_w: avg_power,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::standard_machines;
    use crate::workloads::AppProfile;

    fn app_output(runtime: f64, mem_bound: f64) -> AppOutput {
        AppOutput {
            runtime_s: runtime,
            success: true,
            metrics: Json::obj(),
            files: vec![],
            profile: AppProfile {
                utilization: 0.9,
                mem_bound,
            },
        }
    }

    fn jedi() -> Machine {
        standard_machines()
            .into_iter()
            .find(|m| m.name == "jedi")
            .unwrap()
    }

    #[test]
    fn enriches_metrics_without_touching_files() {
        let m = jedi();
        let mut rng = Prng::new(1);
        let base = app_output(120.0, 0.4);
        let (out, report) = wrap_with_jpwr(base, &m, 2, m.power.nominal_mhz, &mut rng);
        assert!(out.metrics.f64_of("energy_j").unwrap() > 0.0);
        assert_eq!(out.metrics.str_of("launcher"), Some("jpwr"));
        assert_eq!(report.traces.len(), 4); // the 4 GPUs of Fig. 8
        assert_eq!(report.scopes.len(), 4);
        // 2 nodes -> double the node energy
        let node = out.metrics.f64_of("node_energy_j").unwrap();
        let total = out.metrics.f64_of("energy_j").unwrap();
        assert!((total / node - 2.0).abs() < 1e-9);
    }

    #[test]
    fn energy_bowl_over_frequency() {
        // Fig. 9: sweeping frequency produces an interior energy minimum.
        let m = jedi();
        let sweep: Vec<(f64, f64)> = (0..12)
            .map(|i| {
                let f = m.power.min_mhz + i as f64 * (m.power.nominal_mhz - m.power.min_mhz) / 11.0;
                let mut rng = Prng::new(7);
                // runtime grows as frequency drops (compute-bound-ish app)
                let rt = 100.0 / m.power.perf_factor(f, 0.4);
                let (out, _) = wrap_with_jpwr(app_output(rt, 0.4), &m, 1, f, &mut rng);
                (f, out.metrics.f64_of("energy_j").unwrap())
            })
            .collect();
        let min_idx = sweep
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
            .unwrap()
            .0;
        assert!(
            min_idx > 0 && min_idx < sweep.len() - 1,
            "sweet spot must be interior: idx={min_idx} sweep={sweep:?}"
        );
    }

    /// Regression: a CPU-only machine (`gpus_per_node: 0`) must omit the
    /// per-GPU metrics instead of recording NaN `avg_power_w` /
    /// `energy_per_gpu_j` that poison the report JSON.
    #[test]
    fn cpu_only_machine_omits_per_gpu_metrics_without_nan() {
        let mut m = jedi();
        m.gpus_per_node = 0;
        let mut rng = Prng::new(4);
        let (out, report) =
            wrap_with_jpwr(app_output(90.0, 0.5), &m, 2, m.power.nominal_mhz, &mut rng);
        assert!(report.traces.is_empty());
        assert_eq!(out.metrics.f64_of("energy_j"), Some(0.0));
        assert_eq!(out.metrics.f64_of("node_energy_j"), Some(0.0));
        assert_eq!(out.metrics.f64_of("edp"), Some(0.0));
        assert_eq!(out.metrics.f64_of("avg_power_w"), None);
        assert_eq!(out.metrics.f64_of("energy_per_gpu_j"), None);
        assert!(!report.avg_power_w.is_nan());
        // every recorded metric is finite — nothing NaN reaches the report
        for (k, v) in out.metrics.as_obj().unwrap_or(&[]) {
            if let Some(x) = v.as_f64() {
                assert!(x.is_finite(), "{k} = {x}");
            }
        }
    }

    #[test]
    fn edp_is_energy_times_delay() {
        let m = jedi();
        let mut rng = Prng::new(9);
        let (out, _) = wrap_with_jpwr(app_output(120.0, 0.4), &m, 1, m.power.nominal_mhz, &mut rng);
        let e = out.metrics.f64_of("energy_j").unwrap();
        let edp = out.metrics.f64_of("edp").unwrap();
        assert!((edp - e * 120.0).abs() < 1e-6 * edp, "{edp} vs {}", e * 120.0);
    }

    #[test]
    fn longer_runs_use_more_energy() {
        let m = jedi();
        let mut rng = Prng::new(2);
        let (short, _) =
            wrap_with_jpwr(app_output(50.0, 0.5), &m, 1, m.power.nominal_mhz, &mut rng);
        let (long, _) =
            wrap_with_jpwr(app_output(200.0, 0.5), &m, 1, m.power.nominal_mhz, &mut rng);
        assert!(
            long.metrics.f64_of("energy_j").unwrap()
                > 3.0 * short.metrics.f64_of("energy_j").unwrap()
        );
    }
}
