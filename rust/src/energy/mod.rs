//! Energy measurement and system-wide energy studies (paper §VI-B,
//! Figs. 8–9; DESIGN.md §11): the jpwr-like energy-aware launcher and
//! the concurrent `energy-sweep@v1` subsystem built on it.
//!
//! "Energy measurements are obtained by running benchmarks through the
//! energy-aware launcher jpwr. ... The JUBE platform configuration
//! selects jpwr as the launcher" — i.e. the benchmark itself is never
//! modified; the launcher samples per-GPU power while the application
//! runs and the framework post-processes the trace.
//!
//! * [`trace`] — per-GPU power traces with start-up/steady/wind-down
//!   phases sampled from the machine's power model.
//! * [`scope`] — semi-automatic measurement-scope detection: the black
//!   vertical bars of Fig. 8 excluding ramp phases.
//! * [`launcher`] — the jpwr wrapper producing protocol-compliant
//!   `energy_j` / `avg_power_w` / `edp` metrics from an [`AppOutput`].
//! * [`study`] — the `energy-sweep@v1` CI component (all frequency
//!   points interleaved on the shared batch timeline, cache stashed)
//!   and the eligibility-coupled collection campaign behind
//!   `exacb energy` (DESIGN.md §11).
//!
//! [`AppOutput`]: crate::workloads::AppOutput

pub mod launcher;
pub mod scope;
pub mod study;
pub mod trace;

pub use launcher::{wrap_with_jpwr, EnergyReport};
pub use scope::{detect_scope, integrate_energy, Scope};
pub use study::{
    energy_scenario, energy_table, onboard_declared, run_energy_campaign, run_energy_sweep,
    AppSweep, EnergyCampaignOutcome, SweepPolicy, SweepSummary,
};
pub use trace::{sample_trace, PowerTrace};
