//! Energy measurement (paper §VI-B, Figs. 8–9; top layer in the
//! DESIGN.md §1 module map): the jpwr-like energy-aware launcher.
//!
//! "Energy measurements are obtained by running benchmarks through the
//! energy-aware launcher jpwr. ... The JUBE platform configuration
//! selects jpwr as the launcher" — i.e. the benchmark itself is never
//! modified; the launcher samples per-GPU power while the application
//! runs and the framework post-processes the trace.
//!
//! * [`trace`] — per-GPU power traces with start-up/steady/wind-down
//!   phases sampled from the machine's power model.
//! * [`scope`] — semi-automatic measurement-scope detection: the black
//!   vertical bars of Fig. 8 excluding ramp phases.
//! * [`launcher`] — the jpwr wrapper producing protocol-compliant
//!   `energy_j` / `avg_power_w` metrics from an [`AppOutput`].

pub mod launcher;
pub mod scope;
pub mod trace;

pub use launcher::{wrap_with_jpwr, EnergyReport};
pub use scope::{detect_scope, integrate_energy, Scope};
pub use trace::{sample_trace, PowerTrace};
