//! Concurrent, collection-scale energy studies (paper §VI-B, Figs. 8–9;
//! DESIGN.md §11): the `energy-sweep@v1` CI component and the
//! system-wide campaign behind `exacb energy`.
//!
//! A frequency sweep is a *measurement* workload: every point runs the
//! benchmark through the jpwr launcher at one GPU clock. Here all points
//! of a sweep — and, in a campaign, all points of **every eligible
//! application** — are resumable [`ExecutionTask`]s interleaved on the
//! shared batch-system timeline (the same discrete-event dispatch the
//! regression gate uses for its repetitions, §9): every point submits
//! before any simulated time passes, so an 8-point sweep finishes in
//! strictly less simulated time than sequential dispatch whenever the
//! partition can run more than one point at once.
//!
//! Contracts (all tested):
//!
//! * **cache stash** — the execution cache is stashed for the duration
//!   of a sweep: energy measurements need fresh noise, which a replay by
//!   construction cannot provide. A warm re-run of an energy campaign
//!   therefore schedules fresh measurement jobs.
//! * **interleaving-independent noise** — each point draws from its own
//!   PRNG stream (`seed ⊕ fnv1a("energy|pipeline|point-prefix")`), so
//!   concurrent and sequential dispatch produce byte-identical analysis
//!   artifacts (`energy.csv`, `energy.json`).
//! * **eligibility** — campaigns sweep only applications holding the
//!   **reproducibility** rung (the maturity subsystem's energy
//!   eligibility, §10): frequency/energy comparisons are meaningless
//!   without pinned environments and byte-level replayability. Excluded
//!   applications are named in the campaign log.
//! * **sidecar** — per-sweep results land in an `energy.json` CI
//!   artifact (like `cache.json`/`regressions.json`/`maturity.json`),
//!   never in `report.json`; `energy_j`/`edp` flow into
//!   [`crate::tracking::history`] as ordinary recorded metrics, so the
//!   regression gate can fail on energy regressions.

use crate::analysis::{energy_sweep_plot, EnergySweep, ReportSet};
use crate::ci::{CiJob, CiJobState, Pipeline, Trigger};
use crate::coordinator::execution::{ExecPoll, ExecutionParams, ExecutionTask};
use crate::coordinator::executor::Launcher;
use crate::coordinator::repo::BenchmarkRepo;
use crate::coordinator::world::World;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::table::Table;
use crate::workloads::onboarding::OnboardingScenario;
use crate::workloads::portfolio::Maturity;

/// Resolved sweep policy (post component-schema validation).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPolicy {
    /// Explicit frequency list [MHz]; empty = the machine's settable
    /// range sampled at `points` clocks.
    pub frequencies: Vec<f64>,
    /// Grid size of the default sweep.
    pub points: usize,
    /// Metric the study optimises (informational; recorded in the
    /// sidecar so downstream gates know what the sweep was about).
    pub metric: String,
    /// Discrete-event interleaved dispatch (the default) vs the legacy
    /// one-point-at-a-time path.
    pub concurrent: bool,
}

impl SweepPolicy {
    /// Resolve policy inputs, falling back to the canonical catalog
    /// defaults ([`crate::ci::component::energy_sweep_defaults`]) so
    /// schema-resolved and direct callers can never drift apart.
    pub fn from_inputs(inputs: &Json) -> SweepPolicy {
        use crate::ci::component::energy_sweep_defaults as d;
        SweepPolicy {
            frequencies: inputs
                .get("frequencies")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default(),
            points: inputs.u64_of("points").unwrap_or(d::POINTS).clamp(2, 64) as usize,
            metric: inputs.str_of("metric").unwrap_or(d::METRIC).to_string(),
            concurrent: inputs.bool_of("concurrent").unwrap_or(d::CONCURRENT)
                && inputs.str_of("concurrent") != Some("false"),
        }
    }
}

/// The frequency grid of one sweep. An unknown machine is a loud
/// validation error naming the machine (mirroring `Launcher::parse`) —
/// it used to produce an empty default sweep, zero execution jobs, and
/// a misleading "not enough energy points" failure.
fn resolve_frequencies(
    world: &World,
    machine: &str,
    policy: &SweepPolicy,
) -> Result<Vec<f64>, String> {
    let Some(m) = world.cluster.machine(machine) else {
        return Err(format!(
            "unknown machine '{machine}' (an energy sweep needs the machine's settable \
             frequency range)"
        ));
    };
    if !policy.frequencies.is_empty() {
        let mut f: Vec<f64> = policy
            .frequencies
            .iter()
            .cloned()
            .filter(|f| f.is_finite() && *f > 0.0)
            .collect();
        f.sort_by(|a, b| a.partial_cmp(b).unwrap());
        f.dedup_by(|a, b| (*a - *b).abs() < 0.5);
        if f.is_empty() {
            return Err("input 'frequencies' contains no usable values".to_string());
        }
        return Ok(f);
    }
    let (lo, hi) = (m.power.min_mhz, m.power.nominal_mhz);
    let n = policy.points.max(2);
    Ok((0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect())
}

/// The per-point execution parameters: jpwr launcher, pinned clock,
/// per-frequency store prefix (`{base}.f{freq}`).
fn point_params(base: &ExecutionParams, freq: f64) -> ExecutionParams {
    let mut p = base.clone();
    p.launcher = Launcher::Jpwr;
    p.freq_mhz = Some(freq);
    p.prefix = format!("{}.f{freq:.0}", base.prefix);
    p
}

/// Per-point noise stream: independent of how the timeline interleaves
/// the points (concurrent ≡ sequential, byte-identically) and fresh for
/// every new owning pipeline (daily studies re-measure, §4).
fn point_rng(world: &World, pipeline_id: u64, point_prefix: &str) -> Prng {
    Prng::new(
        world.seed
            ^ crate::util::fnv1a(format!("energy|{pipeline_id}|{point_prefix}").as_bytes()),
    )
}

/// One in-flight sweep point: the task plus its repository slot and
/// noise stream.
struct Flight {
    repo_slot: usize,
    task: ExecutionTask,
    rng: Prng,
}

/// Advance one flight, routing it to its repository slot and private
/// noise stream.
fn poll_flight(
    world: &mut World,
    repos: &mut [BenchmarkRepo],
    fl: &mut Flight,
    completed: Option<u64>,
) -> ExecPoll {
    let slot = fl.repo_slot;
    fl.task.poll(world, &mut repos[slot], Some(&mut fl.rng), completed)
}

/// Drive every flight concurrently: poll all to their first submission
/// (so same-trigger points contend for nodes before any simulated time
/// passes), then repeatedly complete the globally earliest batch event
/// across all machines and resume whichever point was waiting on it —
/// `run_campaign_concurrent`-style dispatch at sweep granularity.
fn drive_concurrent(world: &mut World, repos: &mut [BenchmarkRepo], flights: &mut [Flight]) {
    // (machine, jobid) → flight index; jobids are only unique per machine
    let mut pending: std::collections::BTreeMap<(String, u64), usize> =
        std::collections::BTreeMap::new();
    for (i, fl) in flights.iter_mut().enumerate() {
        match poll_flight(world, repos, fl, None) {
            ExecPoll::Waiting { machine, jobid } => {
                pending.insert((machine, jobid), i);
            }
            ExecPoll::Done => {}
        }
    }
    while !pending.is_empty() {
        let next = world
            .batch
            .iter()
            .filter_map(|(name, bs)| bs.peek_next_event().map(|t| (t, name.clone())))
            .min();
        let Some((_, machine)) = next else {
            // no running job anywhere, yet points are still waiting: the
            // awaited jobs can never complete — fail loudly, don't spin
            for &i in pending.values() {
                flights[i].task.abort("energy sweep stalled: job never completes");
            }
            break;
        };
        let completed = world
            .batch
            .get_mut(&machine)
            .and_then(|b| b.advance_next_event());
        if let Some(jobid) = completed {
            // a foreign pipeline's job may complete first; ignore it —
            // its owner re-checks terminal states (like the §9 gate)
            if let Some(i) = pending.remove(&(machine.clone(), jobid)) {
                match poll_flight(world, repos, &mut flights[i], Some(jobid)) {
                    ExecPoll::Waiting { machine, jobid } => {
                        pending.insert((machine, jobid), i);
                    }
                    ExecPoll::Done => {}
                }
            }
        }
    }
}

/// Legacy dispatch: each point drains its machine before the next
/// starts (the pre-§11 `run_energy_study` behaviour, kept so the
/// concurrent-vs-sequential equivalence stays testable).
fn drive_sequential(world: &mut World, repos: &mut [BenchmarkRepo], flights: &mut [Flight]) {
    for fl in flights.iter_mut() {
        let mut completed = None;
        loop {
            match poll_flight(world, repos, fl, completed.take()) {
                ExecPoll::Done => break,
                ExecPoll::Waiting { machine, jobid } => {
                    if let Some(bs) = world.batch.get_mut(&machine) {
                        bs.run_until_idle();
                    }
                    completed = Some(jobid);
                }
            }
        }
    }
}

/// Aggregate view of one completed sweep (what the campaign tables and
/// `energy.json` sidecar are built from).
#[derive(Debug, Clone)]
pub struct SweepSummary {
    pub prefix: String,
    pub machine: String,
    pub points: usize,
    pub sweet_spot_mhz: f64,
    pub edp_spot_mhz: f64,
    pub energy_nominal_j: f64,
    pub energy_spot_j: f64,
    /// Signed (negative = no interior saving; stay at nominal).
    pub saving_vs_nominal: f64,
}

/// Build the analysis job over everything recorded under the sweep's
/// per-frequency prefixes: `energy.csv` + `energy.svg` artifacts, the
/// `energy.json` sidecar, and the honest sweet-spot log line.
fn analysis_job(
    world: &mut World,
    repo: &BenchmarkRepo,
    component: &str,
    base: &ExecutionParams,
    pipeline_id: u64,
    frequencies: &[f64],
    metric: &str,
) -> (CiJob, Option<SweepSummary>) {
    let mut job = CiJob::new(
        world.ids.job_id(),
        &format!("{}.energy-analysis", base.prefix),
    );
    job.state = CiJobState::Running;
    // read via the repo's shared snapshot (DESIGN.md §12): per-sweep
    // analysis jobs stop re-walking the whole branch
    let (set, _) =
        repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, &format!("{}.f", base.prefix)));
    let Some(sweep) = EnergySweep::from_set(&set, &base.prefix) else {
        job.log_line("not enough energy points for a sweep");
        job.state = CiJobState::Failed;
        return (job, None);
    };
    let mut csv = Table::new(&["freq_mhz", "energy_j", "runtime_s", "edp"]);
    let mut pts = Json::arr();
    for ((f, e), (_, t)) in sweep.points.iter().zip(&sweep.runtimes) {
        csv.push_row(vec![
            format!("{f:.0}"),
            format!("{e:.1}"),
            format!("{t:.3}"),
            format!("{:.1}", e * t),
        ]);
        pts.push(
            Json::obj()
                .set("freq_mhz", *f)
                .set("energy_j", *e)
                .set("runtime_s", *t)
                .set("edp", e * t),
        );
    }
    job.add_artifact("energy.csv", &csv.to_csv());
    job.add_artifact(
        "energy.svg",
        &energy_sweep_plot(std::slice::from_ref(&sweep)).render_svg(),
    );
    let mut freq_arr = Json::arr();
    for f in frequencies {
        freq_arr.push(*f);
    }
    let nominal_mhz = sweep.points.last().map(|(f, _)| *f).unwrap_or(0.0);
    let summary = SweepSummary {
        prefix: base.prefix.clone(),
        machine: base.machine.clone(),
        points: sweep.points.len(),
        sweet_spot_mhz: sweep.sweet_spot_mhz,
        edp_spot_mhz: sweep.edp_spot_mhz,
        energy_nominal_j: sweep.energy_at_nominal_j(),
        energy_spot_j: sweep.energy_at_spot_j(),
        saving_vs_nominal: sweep.saving_vs_nominal,
    };
    let doc = Json::obj()
        .set("component", component)
        .set("prefix", base.prefix.as_str())
        .set("machine", base.machine.as_str())
        .set("pipeline_id", pipeline_id)
        .set("commit", repo.commit.as_str())
        .set("metric", metric)
        .set("frequencies", freq_arr)
        .set("points", pts)
        .set("sweet_spot_mhz", sweep.sweet_spot_mhz)
        .set("edp_sweet_spot_mhz", sweep.edp_spot_mhz)
        .set("nominal_mhz", nominal_mhz)
        .set("energy_nominal_j", summary.energy_nominal_j)
        .set("energy_sweet_spot_j", summary.energy_spot_j)
        .set("saving_vs_nominal", sweep.saving_vs_nominal)
        .set(
            "verdict",
            if sweep.saving_vs_nominal > 0.0 {
                "saving"
            } else {
                "no-saving"
            },
        );
    job.add_artifact("energy.json", &doc.pretty());
    job.output = Json::obj()
        .set("sweet_spot_mhz", sweep.sweet_spot_mhz)
        .set("edp_sweet_spot_mhz", sweep.edp_spot_mhz)
        .set("saving_vs_nominal", sweep.saving_vs_nominal);
    job.log_line(format!(
        "sweet spot at {:.0} MHz ({}), EDP optimum at {:.0} MHz",
        sweep.sweet_spot_mhz,
        sweep.saving_label(),
        sweep.edp_spot_mhz
    ));
    job.state = CiJobState::Success;
    (job, Some(summary))
}

/// Run one application's frequency sweep for one pipeline. Returns the
/// per-point execution CI jobs (in frequency order) followed by the
/// analysis job. `component` names the invoking catalog entry in
/// validation jobs and the sidecar; `concurrent_override` forces a
/// dispatch mode regardless of the `concurrent` input (the legacy
/// `jureap/energy@v3` wrapper pins sequential).
pub(crate) fn run_sweep(
    world: &mut World,
    repo: &mut BenchmarkRepo,
    inputs: &Json,
    pipeline_id: u64,
    component: &str,
    concurrent_override: Option<bool>,
) -> Vec<CiJob> {
    let validate_failure = |world: &mut World, err: &str| {
        let mut job = CiJob::new(world.ids.job_id(), &format!("{component}.validate"));
        job.log_line(format!("input validation failed: {err}"));
        job.state = CiJobState::Failed;
        vec![job]
    };
    let mut policy = SweepPolicy::from_inputs(inputs);
    if let Some(c) = concurrent_override {
        policy.concurrent = c;
    }
    let base = match ExecutionParams::from_inputs(inputs) {
        Ok(p) => p,
        Err(e) => return validate_failure(world, &e),
    };
    let freqs = match resolve_frequencies(world, &base.machine, &policy) {
        Ok(f) => f,
        Err(e) => return validate_failure(world, &e),
    };

    // Energy points are measurement runs: stash the cache so every point
    // draws a fresh noise sample instead of replaying a stale report.
    let stashed_cache = world.cache.take();
    let mut flights: Vec<Flight> = freqs
        .iter()
        .map(|&f| {
            let params = point_params(&base, f);
            let rng = point_rng(world, pipeline_id, &params.prefix);
            Flight {
                repo_slot: 0,
                task: ExecutionTask::new(params, pipeline_id),
                rng,
            }
        })
        .collect();
    if crate::obs::metrics_on() {
        crate::obs::count_app(&repo.name, crate::obs::Ctr::EnergySweeps, 1);
        crate::obs::count_app(&repo.name, crate::obs::Ctr::EnergyPoints, flights.len() as u64);
    }
    let sweep_start = world.batch.get(&base.machine).map(|b| b.now());
    {
        let repos = std::slice::from_mut(repo);
        if policy.concurrent {
            drive_concurrent(world, repos, &mut flights);
        } else {
            drive_sequential(world, repos, &mut flights);
        }
    }
    if crate::obs::tracing() {
        let sweep_end = world.batch.get(&base.machine).map(|b| b.now());
        if let (Some(s), Some(e)) = (sweep_start, sweep_end) {
            crate::obs::trace::span(
                &base.machine,
                "energy-sweep",
                s,
                e,
                crate::obs::trace::args(&[
                    ("pipeline", pipeline_id.to_string()),
                    ("repo", repo.name.clone()),
                    ("points", freqs.len().to_string()),
                ]),
            );
        }
    }
    world.cache = stashed_cache;

    let mut jobs: Vec<CiJob> = flights
        .into_iter()
        .flat_map(|fl| fl.task.into_result().0)
        .collect();
    let (job, _) =
        analysis_job(world, repo, component, &base, pipeline_id, &freqs, &policy.metric);
    jobs.push(job);
    jobs
}

/// The `energy-sweep@v1` CI component (dispatched from the coordinator
/// event loop like `regression-check@v1`): a concurrent frequency sweep
/// through the jpwr launcher plus the sweet-spot analysis, honouring
/// the `concurrent` input (default true).
pub fn run_energy_sweep(
    world: &mut World,
    repo: &mut BenchmarkRepo,
    inputs: &Json,
    pipeline_id: u64,
) -> Vec<CiJob> {
    run_sweep(world, repo, inputs, pipeline_id, "energy-sweep@v1", None)
}

/// One application's slot in a campaign outcome.
#[derive(Debug, Clone)]
pub struct AppSweep {
    pub app: String,
    pub machine: String,
    pub pipeline_id: u64,
    /// Every stage of the sweep pipeline succeeded.
    pub ok: bool,
    /// `None` when the analysis could not form a sweep.
    pub summary: Option<SweepSummary>,
}

/// What a collection-wide energy campaign produced.
#[derive(Debug, Clone, Default)]
pub struct EnergyCampaignOutcome {
    pub swept: Vec<AppSweep>,
    /// Applications skipped by the reproducibility-only eligibility
    /// rule, with the rung they actually hold.
    pub excluded: Vec<(String, Maturity)>,
    pub log: Vec<String>,
}

impl EnergyCampaignOutcome {
    /// Per-app sweet spots: the `exacb energy` headline table.
    pub fn sweet_spot_table(&self) -> Table {
        let mut t = Table::new(&[
            "app",
            "machine",
            "points",
            "sweet_spot_mhz",
            "edp_spot_mhz",
            "saving",
        ]);
        if self.swept.is_empty() {
            t.push_placeholder("(no eligible applications swept)");
            return t;
        }
        for s in &self.swept {
            match &s.summary {
                Some(sm) => t.push_row(vec![
                    s.app.clone(),
                    s.machine.clone(),
                    sm.points.to_string(),
                    format!("{:.0}", sm.sweet_spot_mhz),
                    format!("{:.0}", sm.edp_spot_mhz),
                    format!("{:+.1}%", sm.saving_vs_nominal * 100.0),
                ]),
                None => t.push_row(vec![
                    s.app.clone(),
                    s.machine.clone(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "(no sweep)".into(),
                ]),
            }
        }
        t
    }

    /// Projected collection-wide savings: per-app energy at nominal vs
    /// at the sweet spot, with a TOTAL row (apps whose sweep found no
    /// interior saving project 0 — running them slower would *cost*
    /// energy, which the signed per-app column states honestly).
    pub fn savings_table(&self) -> Table {
        let mut t = Table::new(&[
            "app",
            "energy_nominal_j",
            "energy_spot_j",
            "saving",
            "projected_j",
        ]);
        if self.swept.is_empty() {
            t.push_placeholder("(no eligible applications swept)");
            return t;
        }
        let (mut tot_nom, mut tot_proj) = (0.0f64, 0.0f64);
        for s in self.swept.iter() {
            let Some(sm) = &s.summary else { continue };
            let projected = (sm.energy_nominal_j - sm.energy_spot_j).max(0.0);
            tot_nom += sm.energy_nominal_j;
            tot_proj += projected;
            t.push_row(vec![
                s.app.clone(),
                format!("{:.0}", sm.energy_nominal_j),
                format!("{:.0}", sm.energy_spot_j),
                format!("{:+.1}%", sm.saving_vs_nominal * 100.0),
                format!("{projected:.0}"),
            ]);
        }
        t.push_row(vec![
            "TOTAL".into(),
            format!("{tot_nom:.0}"),
            format!("{:.0}", tot_nom - tot_proj),
            format!(
                "{:+.1}%",
                if tot_nom > 0.0 { 100.0 * tot_proj / tot_nom } else { 0.0 }
            ),
            format!("{tot_proj:.0}"),
        ]);
        t
    }

    /// Projected collection saving as a fraction of nominal energy.
    pub fn projected_saving_frac(&self) -> f64 {
        let (mut nom, mut proj) = (0.0f64, 0.0f64);
        for s in &self.swept {
            if let Some(sm) = &s.summary {
                nom += sm.energy_nominal_j;
                proj += (sm.energy_nominal_j - sm.energy_spot_j).max(0.0);
            }
        }
        if nom > 0.0 {
            proj / nom
        } else {
            0.0
        }
    }

    /// Applications whose sweep found a positive sweet-spot saving.
    pub fn apps_with_saving(&self) -> usize {
        self.swept
            .iter()
            .filter(|s| {
                s.summary
                    .as_ref()
                    .map(|sm| sm.saving_vs_nominal > 0.0)
                    .unwrap_or(false)
            })
            .count()
    }
}

/// Run a collection-wide energy campaign: select applications by the
/// maturity subsystem's reproducibility-only energy eligibility, sweep
/// each on its target machine (**every point of every application** on
/// the shared timeline when `concurrent`), and aggregate sweet spots,
/// EDP optima, and the projected savings table. Each application's
/// sweep lands in `world.pipelines` as its own pipeline record with the
/// `energy.json` sidecar on the analysis job.
pub fn run_energy_campaign(
    world: &mut World,
    sc: &OnboardingScenario,
    points: usize,
    concurrent: bool,
) -> EnergyCampaignOutcome {
    let mut out = EnergyCampaignOutcome::default();
    let policy = SweepPolicy {
        frequencies: Vec::new(),
        points,
        metric: "energy_j".to_string(),
        concurrent,
    };
    // ---- eligibility: the maturity subsystem's reproducibility-only
    // rule (§10), consumed rather than re-derived ----------------------
    let eligible_names = crate::maturity::energy_eligible(sc, world);
    out.excluded = crate::maturity::energy_excluded(sc, world);
    for (name, level) in &out.excluded {
        out.log.push(format!(
            "excluded {name}: holds {level}, energy studies need reproducibility"
        ));
    }
    let mut eligible: Vec<usize> = Vec::new();
    for (i, oa) in sc.apps.iter().enumerate() {
        if eligible_names.iter().any(|n| n == &oa.app.name) {
            eligible.push(i);
        } else if world.repo(&oa.app.name).is_none() {
            out.log.push(format!("excluded {}: not onboarded", oa.app.name));
        }
    }
    out.log.push(format!(
        "{} of {} application(s) eligible ({} dispatch)",
        eligible.len(),
        sc.apps.len(),
        if concurrent { "concurrent" } else { "sequential" }
    ));

    // ---- check out every eligible repository, build all points -------
    let stashed_cache = world.cache.take();
    let mut repos: Vec<BenchmarkRepo> = Vec::new();
    // (scenario index, pipeline id, base params, frequencies) per slot
    let mut metas: Vec<(usize, u64, ExecutionParams, Vec<f64>)> = Vec::new();
    let mut flights: Vec<Flight> = Vec::new();
    for &i in &eligible {
        let name = sc.apps[i].app.name.clone();
        let machine = sc.machine_for(i).to_string();
        let freqs = match resolve_frequencies(world, &machine, &policy) {
            Ok(f) => f,
            Err(e) => {
                out.log.push(format!("skipped {name}: {e}"));
                continue;
            }
        };
        let Some(repo) = world.repos.remove(&name) else {
            continue;
        };
        let pipeline_id = world.ids.pipeline_id();
        let base = ExecutionParams {
            prefix: format!("{machine}.{name}"),
            machine,
            queue: sc.queue.clone(),
            project: "cexalab".to_string(),
            budget: "exalab".to_string(),
            jube_file: "benchmark/jube/app.yml".to_string(),
            variant: String::new(),
            usecase: String::new(),
            extra_tags: Vec::new(),
            stage: "2026".to_string(),
            launcher: Launcher::Jpwr,
            record: true,
            freq_mhz: None,
            nodes_override: 0,
            in_command: None,
        };
        let slot = repos.len();
        repos.push(repo);
        for &f in &freqs {
            let params = point_params(&base, f);
            let rng = point_rng(world, pipeline_id, &params.prefix);
            flights.push(Flight {
                repo_slot: slot,
                task: ExecutionTask::new(params, pipeline_id),
                rng,
            });
        }
        metas.push((i, pipeline_id, base, freqs));
    }

    // ---- the shared timeline ----------------------------------------
    if concurrent {
        drive_concurrent(world, &mut repos, &mut flights);
    } else {
        drive_sequential(world, &mut repos, &mut flights);
    }
    world.cache = stashed_cache;

    // ---- per-app analysis + pipeline records ------------------------
    let mut jobs_per_slot: Vec<Vec<CiJob>> = repos.iter().map(|_| Vec::new()).collect();
    for fl in flights {
        jobs_per_slot[fl.repo_slot].extend(fl.task.into_result().0);
    }
    for (slot, (i, pipeline_id, base, freqs)) in metas.into_iter().enumerate() {
        let repo = &repos[slot];
        let (job, summary) = analysis_job(
            world,
            repo,
            "energy-sweep@v1",
            &base,
            pipeline_id,
            &freqs,
            &policy.metric,
        );
        let mut jobs = std::mem::take(&mut jobs_per_slot[slot]);
        jobs.push(job);
        let pipeline = Pipeline {
            id: pipeline_id,
            repo: sc.apps[i].app.name.clone(),
            trigger: Trigger::Scheduled,
            created: world.now(),
            jobs,
        };
        let ok = pipeline.succeeded();
        world.record_pipeline(pipeline);
        out.log.push(match &summary {
            Some(sm) => format!(
                "{}: sweet spot {:.0} MHz ({:+.1}% vs nominal), EDP optimum {:.0} MHz",
                sc.apps[i].app.name,
                sm.sweet_spot_mhz,
                sm.saving_vs_nominal * 100.0,
                sm.edp_spot_mhz
            ),
            None => format!("{}: sweep produced no analysable points", sc.apps[i].app.name),
        });
        out.swept.push(AppSweep {
            app: sc.apps[i].app.name.clone(),
            machine: sc.machine_for(i).to_string(),
            pipeline_id,
            ok,
            summary,
        });
    }
    for repo in repos {
        world.repos.insert(repo.name.clone(), repo);
    }
    out
}

/// The seeded scenario behind `exacb energy` and the perf bench: the
/// generated onboarding portfolio with a deterministic eligible third —
/// every third application is pinned to the verified-reproducibility
/// track (declared reproducibility, instrumented + replay-audited from
/// day 0, never broken), so after `days ≥ 4` of onboarding the campaign
/// is guaranteed a known eligible set while the remaining applications
/// keep their generated levels and exercise the exclusion path.
pub fn energy_scenario(n: usize, days: i64, seed: u64) -> OnboardingScenario {
    let mut sc = OnboardingScenario::generate(n, days, seed);
    for (i, oa) in sc.apps.iter_mut().enumerate() {
        if i % 3 == 0 {
            oa.declared = Maturity::Reproducibility;
            oa.instrument_from = Some(0);
            oa.verify_from = Some(0);
            oa.break_day = None;
            oa.fix_day = None;
        }
    }
    sc
}

/// Onboard the scenario's repositories at their *declared* levels
/// without running a campaign — for benches and tests that want a
/// known eligible set without simulating the onboarding days. (The CLI
/// path earns levels the honest way via `maturity::run_onboarding`.)
pub fn onboard_declared(world: &mut World, sc: &OnboardingScenario) {
    for oa in &sc.apps {
        world.add_repo(
            BenchmarkRepo::new(&oa.app.name)
                .with_file("benchmark/jube/app.yml", &oa.jube_file(0))
                .with_maturity(oa.declared),
        );
    }
}

/// Base prefix of a per-frequency sweep segment: `jedi.app.f800` →
/// `jedi.app`; anything else → `None`.
fn sweep_base(segment: &str) -> Option<&str> {
    let i = segment.rfind(".f")?;
    let digits = &segment[i + 2..];
    if !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit()) {
        Some(&segment[..i])
    } else {
        None
    }
}

/// A-posteriori sweet-spot table over every recorded sweep in the world
/// (the `exacb energy` view; DESIGN.md §11). Reads only the
/// `exacb.data` branches — never executor state.
pub fn energy_table(world: &World) -> Table {
    let mut t = Table::new(&[
        "benchmark",
        "system",
        "points",
        "sweet_spot_mhz",
        "edp_spot_mhz",
        "saving",
    ]);
    let mut rows: Vec<Vec<String>> = Vec::new();
    for repo in world.repos.values() {
        // eligibility scan through the snapshot: list + per-base loads
        // share one O(delta)-refreshed view of the branch
        let mut bases: Vec<String> = repo.with_snapshot(|snap| {
            snap.list("")
                .into_iter()
                .filter_map(|p| sweep_base(p.split('/').next().unwrap_or("")).map(str::to_string))
                .collect()
        });
        bases.sort();
        bases.dedup();
        for base in bases {
            let (set, _) =
                repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, &format!("{base}.f")));
            if let Some(s) = EnergySweep::from_set(&set, &base) {
                let system = set
                    .reports
                    .first()
                    .map(|(_, r)| r.experiment.system.clone())
                    .unwrap_or_default();
                rows.push(vec![
                    base,
                    system,
                    s.points.len().to_string(),
                    format!("{:.0}", s.sweet_spot_mhz),
                    format!("{:.0}", s.edp_spot_mhz),
                    format!("{:+.1}%", s.saving_vs_nominal * 100.0),
                ]);
            }
        }
    }
    rows.sort();
    rows.dedup();
    if rows.is_empty() {
        t.push_placeholder("(no energy sweeps recorded)");
        return t;
    }
    for r in rows {
        t.push_row(r);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_resolves_defaults_and_bounds() {
        let p = SweepPolicy::from_inputs(&Json::obj());
        assert!(p.frequencies.is_empty());
        assert_eq!(p.points, 8);
        assert_eq!(p.metric, "energy_j");
        assert!(p.concurrent);

        let p = SweepPolicy::from_inputs(
            &Json::obj()
                .set("points", 1u64)
                .set("metric", "edp")
                .set("concurrent", "false"),
        );
        assert_eq!(p.points, 2); // clamped up
        assert_eq!(p.metric, "edp");
        assert!(!p.concurrent);
    }

    #[test]
    fn unknown_machine_is_a_loud_error() {
        let world = World::new(1);
        let err = resolve_frequencies(&world, "ghost", &SweepPolicy::from_inputs(&Json::obj()))
            .unwrap_err();
        assert!(err.contains("unknown machine 'ghost'"), "{err}");
    }

    #[test]
    fn default_grid_spans_the_settable_range() {
        let world = World::new(1);
        let f =
            resolve_frequencies(&world, "jedi", &SweepPolicy::from_inputs(&Json::obj())).unwrap();
        let m = world.cluster.machine("jedi").unwrap();
        assert_eq!(f.len(), 8);
        assert!((f[0] - m.power.min_mhz).abs() < 1e-9);
        assert!((f[7] - m.power.nominal_mhz).abs() < 1e-9);
        // explicit lists are sorted, deduped, and filtered
        let p = SweepPolicy {
            frequencies: vec![900.0, 600.0, 900.2, -5.0, f64::NAN],
            ..SweepPolicy::from_inputs(&Json::obj())
        };
        let f = resolve_frequencies(&world, "jedi", &p).unwrap();
        assert_eq!(f, vec![600.0, 900.0]);
    }

    #[test]
    fn sweep_base_parses_frequency_suffixes() {
        assert_eq!(sweep_base("jedi.app.f800"), Some("jedi.app"));
        assert_eq!(sweep_base("jedi.app.f1980"), Some("jedi.app"));
        assert_eq!(sweep_base("jedi.app"), None);
        assert_eq!(sweep_base("jedi.app.fast"), None);
        assert_eq!(sweep_base("jedi.app.f"), None);
    }

    #[test]
    fn energy_table_labels_empty_world() {
        let world = World::new(1);
        let t = energy_table(&world);
        assert_eq!(t.rows.len(), 1);
        assert!(t.rows[0][0].contains("no energy sweeps"), "{:?}", t.rows);
    }

    #[test]
    fn energy_scenario_pins_a_deterministic_eligible_third() {
        let sc = energy_scenario(9, 6, 7);
        for (i, oa) in sc.apps.iter().enumerate() {
            if i % 3 == 0 {
                assert_eq!(oa.declared, Maturity::Reproducibility, "app {i}");
                assert_eq!(oa.instrument_from, Some(0));
                assert_eq!(oa.verify_from, Some(0));
                assert_eq!(oa.break_day, None);
            }
        }
        // onboarding at declared levels makes exactly those eligible at
        // day zero (plus any generated reproducibility apps)
        let mut world = World::new(7);
        onboard_declared(&mut world, &sc);
        let eligible: Vec<&str> = sc
            .apps
            .iter()
            .filter(|oa| {
                world
                    .repo(&oa.app.name)
                    .map(|r| r.maturity == Maturity::Reproducibility)
                    .unwrap_or(false)
            })
            .map(|oa| oa.app.name.as_str())
            .collect();
        assert!(eligible.len() >= 3, "{eligible:?}");
    }
}
