//! Paper-experiment regeneration: one entry point per table/figure of
//! the evaluation section (top layer in the DESIGN.md §1 module map).
//!
//! Every function drives the *full* stack — benchmark repository → CI
//! pipeline → orchestrators → batch scheduler → workload models (PJRT
//! where available) → protocol reports → store → analysis — and returns
//! the same rows/series the paper's figure shows. `benches/` and
//! `examples/` are thin wrappers around these.

use crate::analysis::{EnergySweep, ReportSet, StrongScaling, WeakScaling};
use crate::ci::Trigger;
use crate::cluster::{Cluster, EventLog};
use crate::coordinator::{ablation, BenchmarkRepo, World};
use crate::energy::{detect_scope, sample_trace, Scope};
use crate::util::json::Json;
use crate::util::plot::Plot;
use crate::util::table::Table;
use crate::util::timeutil::SimTime;

/// A regenerated experiment: tabular series + rendered plots.
pub struct ExperimentResult {
    pub id: String,
    pub title: String,
    pub table: Table,
    pub plots: Vec<(String, Plot)>,
    pub notes: Vec<String>,
}

impl ExperimentResult {
    /// Print the paper-style series to stdout. The table itself is CLI
    /// output and always lands on stdout; the header and notes are
    /// narration and honor the log threshold (`--quiet` / `EXACB_LOG`).
    pub fn print(&self) {
        crate::obs_info!("=== {} — {} ===", self.id, self.title);
        print!("{}", self.table.render());
        for n in &self.notes {
            crate::obs_info!("note: {n}");
        }
    }

    /// Write CSV + SVG files under `dir`.
    pub fn save(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let base = self.id.to_lowercase().replace(' ', "_");
        std::fs::write(dir.join(format!("{base}.csv")), self.table.to_csv())?;
        for (name, plot) in &self.plots {
            std::fs::write(
                dir.join(format!("{base}_{name}.svg")),
                plot.render_svg(),
            )?;
        }
        Ok(())
    }
}

/// Repo running a daily benchmark command on a machine.
fn daily_repo(
    name: &str,
    machine: &str,
    queue: &str,
    command: &str,
    analysis: &str,
) -> BenchmarkRepo {
    let jube = format!(
        "name: {name}\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: 1\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - {command}\n{analysis}"
    );
    let ci = format!(
        r#"
include:
  - component: execution@v3
    inputs:
      prefix: "{machine}.{name}"
      machine: "{machine}"
      queue: "{queue}"
      project: "cjsc"
      budget: "zam"
      jube_file: "benchmark/jube/app.yml"
schedule:
  every: day
  hour: 3
"#
    );
    BenchmarkRepo::new(name)
        .with_file("benchmark/jube/app.yml", &jube)
        .with_file(".gitlab-ci.yml", &ci)
}

fn run_daily(world: &mut World, repo: &str, days: i64) {
    for d in 0..days {
        world.advance_to(SimTime::from_days(d).add_secs(3 * 3600));
        world
            .run_pipeline(repo, Trigger::Scheduled)
            .expect("pipeline runs");
    }
}

/// Table I: the `results.csv` minimum-column contract, produced by an
/// actual pipeline run of the §II logmap example.
pub fn table1(world_seed: u64) -> ExperimentResult {
    let mut world = World::new(world_seed);
    world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
    let pid = world.run_pipeline("logmap", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    let csv = p
        .job("jedi.logmap.execute")
        .and_then(|j| j.artifact("results.csv"))
        .unwrap_or("");
    let table = Table::from_csv(csv).unwrap_or_default();
    ExperimentResult {
        id: "Table I".into(),
        title: "results.csv column contract".into(),
        table,
        plots: vec![],
        notes: vec![
            "columns: system version queue variant jobid nodes taskspernode threadspertasks runtime success + additional_metrics".into(),
        ],
    }
}

/// Fig. 2: integration-mode ablation (§III quadrants).
pub fn fig2(seed: u64) -> ExperimentResult {
    let (_outcomes, table) = ablation::run_ablation(70, 10, seed);
    ExperimentResult {
        id: "Fig 2".into(),
        title: "centralization x coupling ablation".into(),
        table,
        plots: vec![],
        notes: vec!["paper picks quadrant 2 (distributed+tight) as most balanced".into()],
    }
}

/// Fig. 3: BabelStream five-kernel bandwidth time series on JUPITER —
/// expected: flat (stable system component).
pub fn fig3(days: i64, seed: u64) -> ExperimentResult {
    let mut world = World::new(seed);
    world.add_repo(daily_repo("stream", "jupiter", "all", "babelstream", ""));
    run_daily(&mut world, "stream", days);

    let labels = ["copy", "mul", "add", "triad", "dot"];
    let repo = world.repo("stream").unwrap();
    let (set, _) = repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, "jupiter.stream/"));
    let mut table = Table::new(&["date", "copy", "mul", "add", "triad", "dot"]);
    let series: Vec<Vec<(SimTime, f64)>> = labels
        .iter()
        .map(|l| set.time_series(&format!("bw_{l}")))
        .collect();
    for i in 0..series[0].len() {
        let mut row = vec![series[0][i].0.date_string()];
        for s in &series {
            row.push(format!("{:.0}", s[i].1));
        }
        table.push_row(row);
    }
    let analyses: Vec<_> = labels
        .iter()
        .map(|l| crate::analysis::analyse(&set, &format!("bw_{l}"), 8.0))
        .collect();
    let stable = analyses.iter().all(|a| a.is_stable());
    let plot = crate::analysis::timeseries::plot(
        "BabelStream (GPU) over time (Fig. 3)",
        "Bandwidth / MB/s",
        &analyses,
        &["Copy kernel".into(), "Multiply kernel".into(), "Add kernel".into(),
          "Triad kernel".into(), "Dot kernel".into()],
    );
    ExperimentResult {
        id: "Fig 3".into(),
        title: "BabelStream bandwidth time series (stable)".into(),
        table,
        plots: vec![("timeseries".into(), plot)],
        notes: vec![format!(
            "all five kernels stable: {stable} (paper: performance remains constant)"
        )],
    }
}

/// Fig. 4: Graph500 two-kernel time series with a network regression at
/// day 30 and recovery at day 60.
pub fn fig4(days: i64, seed: u64) -> ExperimentResult {
    let cluster = Cluster::standard().with_events(EventLog::fig4_scenario("jupiter"));
    let mut world = World::with_cluster(cluster, seed);
    world.add_repo(daily_repo(
        "graph500",
        "jupiter",
        "all",
        "graph500 --scale 14 --nbfs 4",
        "",
    ));
    run_daily(&mut world, "graph500", days);

    let repo = world.repo("graph500").unwrap();
    let (set, _) =
        repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, "jupiter.graph500/"));
    let bfs = set.time_series("bfs_gteps");
    let sssp = set.time_series("sssp_gteps");
    let mut table = Table::new(&["date", "bfs_gteps", "sssp_gteps"]);
    for (i, (t, v)) in bfs.iter().enumerate() {
        table.push_row(vec![
            t.date_string(),
            format!("{v:.3}"),
            format!("{:.3}", sssp.get(i).map(|(_, v)| *v).unwrap_or(f64::NAN)),
        ]);
    }
    let analyses = vec![
        crate::analysis::analyse(&set, "bfs_gteps", 8.0),
        crate::analysis::analyse(&set, "sssp_gteps", 8.0),
    ];
    let n_regressions: usize = analyses
        .iter()
        .map(|a| a.changepoints.iter().filter(|c| c.after < c.before).count())
        .sum();
    let n_recoveries: usize = analyses
        .iter()
        .map(|a| a.changepoints.iter().filter(|c| c.after > c.before).count())
        .sum();
    let plot = crate::analysis::timeseries::plot(
        "GRAPH500 over time (Fig. 4)",
        "GTEPS",
        &analyses,
        &["BFS kernel".into(), "SSSP kernel".into()],
    );
    ExperimentResult {
        id: "Fig 4".into(),
        title: "Graph500 time series (regression + recovery)".into(),
        table,
        plots: vec![("timeseries".into(), plot)],
        notes: vec![format!(
            "detected {n_regressions} regression(s) and {n_recoveries} recovery(ies) \
             (paper: visible changes due to system changes)"
        )],
    }
}

/// Fig. 5: strong-scaling comparison of JEDI vs JUWELS-Booster vs
/// JURECA-DC with 80% bands; Ampere result halved for comparability.
pub fn fig5(seed: u64) -> ExperimentResult {
    let mut world = World::new(seed);
    let node_counts = "[1, 2, 4, 8, 16, 32]";
    for (machine, queue) in [
        ("jedi", "all"),
        ("juwels-booster", "booster"),
        ("jureca", "dc-gpu"),
    ] {
        let jube = format!(
            "name: scalingapp\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        values: {node_counts}\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name scalingapp --flops 800000 --serial 0.01 --membound 0.4 --comm-mb 96 --steps 150\n"
        );
        let ci = format!(
            r#"
include:
  - component: execution@v3
    inputs:
      prefix: "{machine}.scaling"
      machine: "{machine}"
      queue: "{queue}"
      project: "cjsc"
      budget: "zam"
      jube_file: "benchmark/jube/app.yml"
"#
        );
        let repo = BenchmarkRepo::new(&format!("scaling-{machine}"))
            .with_file("benchmark/jube/app.yml", &jube)
            .with_file(".gitlab-ci.yml", &ci);
        world.add_repo(repo);
        world
            .run_pipeline(&format!("scaling-{machine}"), Trigger::Manual)
            .unwrap();
    }
    // merge the three repos' data branches
    let mut merged = ReportSet::default();
    for machine in ["jedi", "juwels-booster", "jureca"] {
        let repo = world.repo(&format!("scaling-{machine}")).unwrap();
        let (set, _) = repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, ""));
        merged.reports.extend(set.reports);
    }
    let systems = merged.systems();
    let mut table = Table::new(&["system", "nodes", "runtime", "speedup", "efficiency"]);
    let mut notes = Vec::new();
    for system in &systems {
        let s = StrongScaling::from_set(&merged, system, "runtime").unwrap();
        for (i, &(n, t)) in s.runtimes.iter().enumerate() {
            table.push_row(vec![
                system.clone(),
                n.to_string(),
                format!("{t:.3}"),
                format!("{:.2}", s.speedups[i].1),
                format!("{:.3}", s.efficiencies[i].1),
            ]);
        }
        notes.push(format!(
            "{system}: 80% scaling regime up to {} nodes",
            s.scaling_limit(0.8).unwrap_or(0)
        ));
    }
    // generational gap at 4 nodes
    let at4 = |sys: &str| {
        merged
            .filter_system(sys)
            .nodes_medians("runtime")
            .iter()
            .find(|(n, _)| *n == 4)
            .map(|(_, t)| *t)
            .unwrap_or(f64::NAN)
    };
    notes.push(format!(
        "Ampere/Hopper-class gap at 4 nodes: {:.2}x (paper superimposes /2 for comparability)",
        at4("juwels-booster") / at4("jedi")
    ));
    let plot = crate::analysis::machine_comparison_plot(
        &merged,
        &systems,
        "runtime",
        80.0,
        &["juwels-booster".into(), "jureca".into()],
    );
    ExperimentResult {
        id: "Fig 5".into(),
        title: "strong scaling: JEDI vs JUWELS Booster vs JURECA-DC".into(),
        table,
        plots: vec![("comparison".into(), plot)],
        notes,
    }
}

/// Fig. 6: OSU pt2pt bandwidth vs message size under six
/// `UCX_RNDV_THRESH` values via feature injection.
pub fn fig6(seed: u64) -> ExperimentResult {
    let mut world = World::new(seed);
    let thresholds: [u64; 6] = [1024, 8192, 65536, 262144, 1048576, 4194304];
    let jube = "name: osu\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: 2\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - osu_bw\n";
    let mut curves: Vec<(u64, Vec<(f64, f64)>)> = Vec::new();
    for &thresh in &thresholds {
        let name = format!("osu-t{thresh}");
        let ci = format!(
            r#"
include:
  - component: feature-injection@v3
    inputs:
      prefix: "jupiter.osu.t{thresh}"
      machine: "jupiter"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "benchmark/jube/app.yml"
      in_command: "export UCX_RNDV_THRESH=intra:{thresh},inter:{thresh}"
"#
        );
        let repo = BenchmarkRepo::new(&name)
            .with_file("benchmark/jube/app.yml", jube)
            .with_file(".gitlab-ci.yml", &ci);
        world.add_repo(repo);
        world.run_pipeline(&name, Trigger::Manual).unwrap();
        let repo = world.repo(&name).unwrap();
        let (set, _) = repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, ""));
        // the bw table is a nested metric: [[size, bw], ...]
        let mut curve = Vec::new();
        for (_, r) in &set.reports {
            for e in &r.data {
                if let Some(rows) = e.metrics.get("bw_mbs").and_then(Json::as_arr) {
                    for row in rows {
                        let p = row.as_arr().unwrap();
                        curve.push((p[0].as_f64().unwrap(), p[1].as_f64().unwrap()));
                    }
                }
            }
        }
        curves.push((thresh, curve));
    }
    let mut table = Table::new(&[
        "msg_bytes", "t1024", "t8192", "t65536", "t262144", "t1048576", "t4194304",
    ]);
    let sizes: Vec<f64> = curves[0].1.iter().map(|(s, _)| *s).collect();
    for (i, size) in sizes.iter().enumerate() {
        let mut row = vec![format!("{size:.0}")];
        for (_, c) in &curves {
            row.push(format!("{:.0}", c[i].1));
        }
        table.push_row(row);
    }
    let mut plot = Plot::new(
        "OSU bandwidth vs message size under UCX_RNDV_THRESH (Fig. 6)",
        "message size [B]",
        "bandwidth [MB/s]",
    )
    .logx()
    .logy();
    for (thresh, curve) in &curves {
        plot.add(crate::util::plot::Series::new(
            &format!("RNDV_THRESH={thresh}"),
            curve.clone(),
        ));
    }
    ExperimentResult {
        id: "Fig 6".into(),
        title: "OSU bandwidth under six UCX_RNDV_THRESH values".into(),
        table,
        plots: vec![("osu".into(), plot)],
        notes: vec![
            "curves diverge between threshold values: eager vs rendezvous crossover".into(),
        ],
    }
}

/// Fig. 7: weak scaling under software stages 2025 vs 2026.
pub fn fig7(seed: u64) -> ExperimentResult {
    let mut world = World::new(seed);
    let jube = "name: weakapp\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        values: [1, 2, 4, 8, 16, 32, 64]\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name weakapp --weak --flops 120000 --membound 0.55 --comm-mb 128 --steps 220\n";
    let mut curves = Vec::new();
    let mut table = Table::new(&["stage", "nodes", "runtime", "efficiency"]);
    for stage in ["2025", "2026"] {
        let name = format!("weak-{stage}");
        let ci = format!(
            r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jupiter.weak.{stage}"
      machine: "jupiter"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "benchmark/jube/app.yml"
      stage: "{stage}"
"#
        );
        let repo = BenchmarkRepo::new(&name)
            .with_file("benchmark/jube/app.yml", jube)
            .with_file(".gitlab-ci.yml", &ci);
        world.add_repo(repo);
        world.run_pipeline(&name, Trigger::Manual).unwrap();
        let repo = world.repo(&name).unwrap();
        let (set, _) = repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, ""));
        let w = WeakScaling::from_set(&set, &format!("stage {stage}"), "runtime").unwrap();
        for (i, &(n, t)) in w.runtimes.iter().enumerate() {
            table.push_row(vec![
                stage.to_string(),
                n.to_string(),
                format!("{t:.3}"),
                format!("{:.3}", w.efficiencies[i].1),
            ]);
        }
        curves.push(w);
    }
    let eff_at = |c: &WeakScaling, n: u64| {
        c.efficiencies
            .iter()
            .find(|(m, _)| *m == n)
            .map(|(_, e)| *e)
            .unwrap_or(f64::NAN)
    };
    let notes = vec![format!(
        "stage-2026 efficiency at 64 nodes: {:.3}; stage-2025: {:.3} (paper: update guidance + weak-scaling capacity)",
        eff_at(&curves[1], 64),
        eff_at(&curves[0], 64)
    )];
    let plot = crate::analysis::weak_scaling_plot(&curves);
    ExperimentResult {
        id: "Fig 7".into(),
        title: "weak scaling across software stages".into(),
        table,
        plots: vec![("weak".into(), plot)],
        notes,
    }
}

/// Fig. 8: per-GPU power traces with measurement-scope bars for one run.
pub fn fig8(seed: u64) -> ExperimentResult {
    let cluster = Cluster::standard();
    let machine = cluster.machine("jedi").unwrap().clone();
    let mut rng = crate::util::prng::Prng::new(seed);
    let profile = crate::workloads::logmap::PROFILE;
    let runtime_s = 180.0;
    let mut table = Table::new(&[
        "gpu", "scope_start_s", "scope_end_s", "scoped_energy_j", "avg_power_w",
    ]);
    let mut plot = Plot::new(
        "Energy-to-solution measurement (Fig. 8)",
        "time [s]",
        "power [W]",
    );
    let mut scopes: Vec<Scope> = Vec::new();
    for gpu in 0..machine.gpus_per_node as usize {
        let trace = sample_trace(
            gpu,
            &machine.power,
            profile,
            machine.power.nominal_mhz,
            runtime_s,
            &mut rng,
        );
        let scope = detect_scope(&trace, machine.power.idle_w, 0.5).unwrap();
        let e = crate::energy::integrate_energy(&trace, scope);
        table.push_row(vec![
            format!("GPU {gpu}"),
            format!("{:.0}", scope.start as f64 * trace.dt_s),
            format!("{:.0}", scope.end as f64 * trace.dt_s),
            format!("{e:.0}"),
            format!("{:.1}", e / (scope.len() as f64 * trace.dt_s)),
        ]);
        plot.add(crate::util::plot::Series::new(
            &format!("GPU {gpu}"),
            trace
                .samples
                .iter()
                .enumerate()
                .map(|(i, &p)| (i as f64 * trace.dt_s, p))
                .collect(),
        ));
        scopes.push(scope);
    }
    // the paper's black vertical bars (shared scope, first GPU's)
    plot.add_vmark(scopes[0].start as f64, "scope start");
    plot.add_vmark(scopes[0].end as f64, "scope end");
    ExperimentResult {
        id: "Fig 8".into(),
        title: "4-GPU power trace with measurement scope".into(),
        table,
        plots: vec![("power".into(), plot)],
        notes: vec!["scope excludes start-up and wind-down (systematic underestimate)".into()],
    }
}

/// Fig. 9: energy-vs-frequency sweet spots for two applications, via the
/// full energy-study orchestrator.
pub fn fig9(seed: u64) -> ExperimentResult {
    let mut world = World::new(seed);
    // two apps with different memory-boundedness -> different sweet spots
    let apps = [
        (
            "appcompute",
            "simapp --name appcompute --flops 250000 --membound 0.15 --comm-mb 16 --steps 40",
        ),
        (
            "appmemory",
            "simapp --name appmemory --flops 250000 --membound 0.85 --comm-mb 16 --steps 40",
        ),
    ];
    let mut table = Table::new(&["app", "freq_mhz", "energy_j"]);
    let mut sweeps = Vec::new();
    for (name, command) in apps {
        let jube = format!(
            "name: {name}\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: 1\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - {command}\n"
        );
        let ci = format!(
            r#"
include:
  - component: jureap/energy@v3
    inputs:
      prefix: "jedi.{name}"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "benchmark/jube/app.yml"
      frequencies: []
"#
        );
        let repo = BenchmarkRepo::new(name)
            .with_file("benchmark/jube/app.yml", &jube)
            .with_file(".gitlab-ci.yml", &ci);
        world.add_repo(repo);
        world.run_pipeline(name, Trigger::Manual).unwrap();
        let repo = world.repo(name).unwrap();
        let (set, _) = repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, ""));
        // reports live under the execution prefix "jedi.{name}", which is
        // what from_set filters on (DESIGN.md §11)
        let sweep =
            EnergySweep::from_set(&set, &format!("jedi.{name}")).expect("sweep has points");
        for &(f, e) in &sweep.points {
            table.push_row(vec![
                name.to_string(),
                format!("{f:.0}"),
                format!("{e:.0}"),
            ]);
        }
        sweeps.push(sweep);
    }
    let notes = vec![
        format!(
            "{}: sweet spot {:.0} MHz ({:.0}% saving)",
            sweeps[0].app,
            sweeps[0].sweet_spot_mhz,
            sweeps[0].saving_vs_nominal * 100.0
        ),
        format!(
            "{}: sweet spot {:.0} MHz ({:.0}% saving) — memory-bound app throttles lower",
            sweeps[1].app,
            sweeps[1].sweet_spot_mhz,
            sweeps[1].saving_vs_nominal * 100.0
        ),
    ];
    let plot = crate::analysis::energy_sweep_plot(&sweeps);
    ExperimentResult {
        id: "Fig 9".into(),
        title: "energy sweet spots under frequency variation".into(),
        table,
        plots: vec![("energy".into(), plot)],
        notes,
    }
}

/// All experiments in paper order (days controls the Fig. 3/4 span).
pub fn run_all(days: i64, seed: u64) -> Vec<ExperimentResult> {
    vec![
        table1(seed),
        fig2(seed),
        fig3(days, seed),
        fig4(days, seed),
        fig5(seed),
        fig6(seed),
        fig7(seed),
        fig8(seed),
        fig9(seed),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_contract_columns() {
        let r = table1(1);
        assert_eq!(
            &r.table.columns[..10],
            &crate::protocol::BASE_COLUMNS
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()[..]
        );
        assert!(!r.table.is_empty());
    }

    #[test]
    fn fig3_stable_series() {
        let r = fig3(12, 3);
        assert_eq!(r.table.len(), 12);
        assert!(r.notes[0].contains("stable: true"), "{}", r.notes[0]);
    }

    #[test]
    fn fig4_detects_both_changepoints() {
        let r = fig4(90, 4);
        assert_eq!(r.table.len(), 90);
        assert!(
            r.notes[0].contains("1 regression") || r.notes[0].contains("2 regression"),
            "{}",
            r.notes[0]
        );
        assert!(r.notes[0].contains("recover"), "{}", r.notes[0]);
        // dip visible in raw numbers: day 45 bfs < 0.9 * day 10 bfs
        let bfs_at = |row: usize| r.table.rows[row][1].parse::<f64>().unwrap();
        assert!(bfs_at(45) < 0.9 * bfs_at(10));
        assert!((bfs_at(75) / bfs_at(10) - 1.0).abs() < 0.15);
    }

    #[test]
    fn fig5_generational_ordering() {
        let r = fig5(5);
        // 3 systems x 6 node counts
        assert_eq!(r.table.len(), 18);
        let gap_note = r.notes.iter().find(|n| n.contains("gap")).unwrap();
        // extract the gap factor
        let gap: f64 = gap_note
            .split(' ')
            .find_map(|w| w.strip_suffix('x').and_then(|v| v.parse().ok()))
            .unwrap();
        assert!(gap > 1.8 && gap < 5.0, "{gap_note}");
    }

    #[test]
    fn fig6_curves_differ_at_mid_sizes() {
        let r = fig6(6);
        assert_eq!(r.table.len(), 23);
        // at 64 KiB, the 1024-threshold (rndv) and 4M-threshold (eager)
        // columns should differ measurably
        let row = r
            .table
            .rows
            .iter()
            .find(|row| row[0] == "65536")
            .unwrap();
        let low: f64 = row[1].parse().unwrap();
        let high: f64 = row[6].parse().unwrap();
        assert!((low - high).abs() / low.min(high) > 0.03, "{row:?}");
    }

    #[test]
    fn fig7_stage_2026_wins() {
        let r = fig7(7);
        assert_eq!(r.table.len(), 14);
        // compare stage runtimes at 64 nodes
        let rt = |stage: &str| {
            r.table
                .rows
                .iter()
                .find(|row| row[0] == stage && row[1] == "64")
                .unwrap()[2]
                .parse::<f64>()
                .unwrap()
        };
        assert!(rt("2025") > rt("2026"));
    }

    #[test]
    fn fig8_four_gpus_with_scope() {
        let r = fig8(8);
        assert_eq!(r.table.len(), 4);
        assert_eq!(r.plots[0].1.series.len(), 4);
        assert_eq!(r.plots[0].1.vmarks.len(), 2);
    }

    #[test]
    fn fig9_memory_bound_spot_is_lower() {
        let r = fig9(9);
        let spot = |note: &str| -> f64 {
            note.split("sweet spot ")
                .nth(1)
                .unwrap()
                .split(' ')
                .next()
                .unwrap()
                .parse()
                .unwrap()
        };
        let compute = spot(&r.notes[0]);
        let memory = spot(&r.notes[1]);
        assert!(
            memory < compute,
            "memory-bound spot {memory} should be below compute-bound {compute}"
        );
    }
}
