//! The `regression-check@v1` CI component: the gate that closes the
//! continuous-benchmarking loop (DESIGN.md §9).
//!
//! Policy (cbdr-style adaptive resampling):
//!
//! 1. Reconstruct per-series history from the repository's `exacb.data`
//!    branch and split it at the current pipeline id: earlier points are
//!    the **baseline** (last `baseline_window` of them), points from
//!    this pipeline onwards are the **candidate**.
//! 2. Classify candidate vs baseline with a Welch CI
//!    ([`super::detect::Detector`]). While the candidate sample is
//!    below `min_repetitions` or the verdict is *inconclusive*, schedule
//!    extra repetition jobs: full execution runs driven concurrently
//!    through the batch system's discrete-event API
//!    (`peek_next_event`/`advance_next_event`), each recording a fresh
//!    report — until the interval clears a threshold or the
//!    `max_extra_repetitions` budget is exhausted.
//! 3. Pass or fail the pipeline, attaching the verdict as a
//!    `regressions.json` artifact — a sidecar like `cache.json`, never
//!    part of `report.json`.
//!
//! The execution cache is stashed for the duration of the gate: a
//! repetition exists to draw a *fresh* noise sample, which a cache
//! replay by construction cannot provide.

use crate::ci::{CiJob, CiJobState};
use crate::coordinator::execution::{ExecPoll, ExecutionParams, ExecutionTask};
use crate::coordinator::repo::BenchmarkRepo;
use crate::coordinator::world::World;
use crate::util::json::Json;
use crate::util::prng::Prng;

use super::detect::{Classification, Detector, Verdict};
use super::history::History;

/// Resolved gate policy (post component-schema validation).
#[derive(Debug, Clone, PartialEq)]
pub struct GatePolicy {
    pub metric: String,
    pub threshold_pct: f64,
    pub confidence: f64,
    /// Adaptive minimum candidate sample size before deciding.
    pub min_repetitions: usize,
    /// Hard budget of extra repetition runs the gate may schedule.
    pub max_extra_repetitions: usize,
    /// Rolling baseline: how many of the latest pre-pipeline points.
    pub baseline_window: usize,
    /// Baseline points required before the gate is active at all
    /// (younger repositories pass with verdict `no-baseline`).
    pub min_baseline: usize,
}

impl GatePolicy {
    /// Resolve policy inputs, falling back to the canonical catalog
    /// defaults ([`crate::ci::component::regression_check_defaults`]) so
    /// schema-resolved and direct callers can never drift apart.
    pub fn from_inputs(inputs: &Json) -> GatePolicy {
        use crate::ci::component::regression_check_defaults as d;
        let confidence_pct = inputs
            .u64_of("confidence_pct")
            .unwrap_or(d::CONFIDENCE_PCT)
            .clamp(50, 99);
        GatePolicy {
            metric: inputs.str_of("metric").unwrap_or(d::METRIC).to_string(),
            threshold_pct: inputs
                .u64_of("threshold_pct")
                .unwrap_or(d::THRESHOLD_PCT)
                .max(1) as f64,
            confidence: confidence_pct as f64 / 100.0,
            min_repetitions: inputs
                .u64_of("min_repetitions")
                .unwrap_or(d::MIN_REPETITIONS)
                .max(2) as usize,
            max_extra_repetitions: inputs
                .u64_of("max_extra_repetitions")
                .unwrap_or(d::MAX_EXTRA_REPETITIONS) as usize,
            baseline_window: inputs
                .u64_of("baseline_window")
                .unwrap_or(d::BASELINE_WINDOW)
                .max(2) as usize,
            min_baseline: inputs
                .u64_of("min_baseline")
                .unwrap_or(d::MIN_BASELINE)
                .max(2) as usize,
        }
    }

    pub fn detector(&self) -> Detector {
        Detector {
            confidence: self.confidence,
            threshold_pct: self.threshold_pct,
        }
    }
}

/// One series' classification inside a gate evaluation.
struct SeriesEval {
    benchmark: String,
    system: String,
    nodes: u64,
    baseline_pipelines: (u64, u64),
    candidate_commit: String,
    classification: Classification,
}

/// Pull newly recorded reports under `prefix/` into the history.
/// Already-seen store paths are skipped, so refinement rounds parse
/// only the repetitions they just recorded instead of re-reading the
/// whole branch every iteration.
fn ingest_new_reports(
    hist: &mut History,
    known: &mut std::collections::BTreeSet<String>,
    repo: &BenchmarkRepo,
    prefix: &str,
) {
    for path in repo.store.list("exacb.data", &format!("{prefix}/")) {
        if !path.ends_with("report.json") || known.contains(&path) {
            continue;
        }
        let benchmark = path.split('/').next().unwrap_or("").to_string();
        if let Ok(doc) = repo.store.read("exacb.data", &path) {
            hist.ingest(&benchmark, doc);
        }
        known.insert(path);
    }
}

/// Split each series at `pipeline_id` and classify. A series may have
/// no candidate data yet — e.g. a cache-warm replay whose byte-identical
/// report deduped out of history, or a node count the current definition
/// no longer runs; it classifies as `no-baseline` (young) or
/// `inconclusive` (armed, needs repetitions) and the gate loop decides
/// which it was.
fn evaluate(hist: &History, policy: &GatePolicy, pipeline_id: u64) -> Vec<SeriesEval> {
    let det = policy.detector();
    let mut out = Vec::new();
    for series in hist.series() {
        let baseline_pts: Vec<_> = series
            .points
            .iter()
            .filter(|p| p.pipeline_id < pipeline_id)
            .collect();
        let candidate_pts: Vec<_> = series
            .points
            .iter()
            .filter(|p| p.pipeline_id >= pipeline_id)
            .collect();
        let window_start = baseline_pts.len().saturating_sub(policy.baseline_window);
        let window = &baseline_pts[window_start..];
        let baseline: Vec<f64> = window.iter().map(|p| p.value).collect();
        let candidate: Vec<f64> = candidate_pts.iter().map(|p| p.value).collect();
        let classification = if baseline.len() < policy.min_baseline {
            // too young to judge: report as no-baseline, never gate
            let mut c = det.classify(&baseline, &candidate);
            c.verdict = Verdict::NoBaseline;
            c.interval = None;
            c
        } else {
            det.classify(&baseline, &candidate)
        };
        out.push(SeriesEval {
            benchmark: series.key.benchmark.clone(),
            system: series.key.system.clone(),
            nodes: series.key.nodes,
            baseline_pipelines: (
                window.first().map(|p| p.pipeline_id).unwrap_or(0),
                window.last().map(|p| p.pipeline_id).unwrap_or(0),
            ),
            candidate_commit: candidate_pts
                .last()
                .map(|p| p.commit.clone())
                .unwrap_or_default(),
            classification,
        })
    }
    out
}

/// Run `n` extra repetitions of the execution component concurrently on
/// the shared timeline: every task is polled to its first submission,
/// then the machine's discrete-event API completes one job at a time
/// and resumes whichever repetition was waiting on it. Each repetition
/// records under a fresh pipeline id, so its report is a distinct
/// history point with honest provenance.
fn run_repetitions(
    world: &mut World,
    repo: &mut BenchmarkRepo,
    base: &ExecutionParams,
    n: usize,
    mut rng: Option<&mut Prng>,
) -> Vec<CiJob> {
    let machine = base.machine.clone();
    let mut tasks: Vec<ExecutionTask> = (0..n)
        .map(|_| {
            let rep_pid = world.ids.pipeline_id();
            ExecutionTask::new(base.clone(), rep_pid)
        })
        .collect();
    let mut pending: Vec<(usize, u64)> = Vec::new();
    for (i, task) in tasks.iter_mut().enumerate() {
        match task.poll(world, repo, rng.as_deref_mut(), None) {
            ExecPoll::Waiting { jobid, .. } => pending.push((i, jobid)),
            ExecPoll::Done => {}
        }
    }
    while !pending.is_empty() {
        let completed = world
            .batch
            .get_mut(&machine)
            .and_then(|b| b.advance_next_event());
        let Some(jobid) = completed else {
            // no running job can ever complete: fail loudly, don't spin
            for (i, _) in pending.drain(..) {
                tasks[i].abort("regression-gate repetition stalled");
            }
            break;
        };
        // a job of another in-flight pipeline may complete first; ignore
        // it here — the outer event loop re-checks terminal states and
        // resumes its owner
        if let Some(pos) = pending.iter().position(|&(_, j)| j == jobid) {
            let (i, _) = pending.remove(pos);
            match tasks[i].poll(world, repo, rng.as_deref_mut(), Some(jobid)) {
                ExecPoll::Waiting { jobid, .. } => pending.push((i, jobid)),
                ExecPoll::Done => {}
            }
        }
    }
    tasks
        .into_iter()
        .flat_map(|t| t.into_result().0)
        .collect()
}

fn interval_json(c: &Classification) -> Json {
    match &c.interval {
        Some(ci) => {
            let scale = c.mean_baseline.abs().max(1e-300);
            Json::obj()
                .set("lo", ci.lo)
                .set("hi", ci.hi)
                .set("lo_pct", 100.0 * ci.lo / scale)
                .set("hi_pct", 100.0 * ci.hi / scale)
                .set("confidence", ci.confidence)
        }
        None => Json::Null,
    }
}

/// Run the regression gate for one pipeline. Returns the repetition CI
/// jobs (if any were scheduled) followed by the gate job itself.
///
/// `rng` selects the repetition noise stream: the owning pipeline's
/// per-item stream in concurrent campaigns (so a gate's measurements
/// stay independent of which other pipelines share the timeline), or
/// `None` for the world PRNG on the sequential path.
pub fn run_regression_gate(
    world: &mut World,
    repo: &mut BenchmarkRepo,
    inputs: &Json,
    pipeline_id: u64,
    mut rng: Option<&mut Prng>,
) -> Vec<CiJob> {
    let policy = GatePolicy::from_inputs(inputs);
    let params = match ExecutionParams::from_inputs(inputs) {
        Ok(p) => p,
        Err(e) => {
            let mut job = CiJob::new(world.ids.job_id(), "regression-check@v1.validate");
            job.log_line(format!("input validation failed: {e}"));
            job.state = CiJobState::Failed;
            return vec![job];
        }
    };
    let mut job = CiJob::new(
        world.ids.job_id(),
        &format!("{}.regression-check", params.prefix),
    );
    job.state = CiJobState::Running;

    // Repetitions are measurement runs: stash the cache so they draw
    // fresh noise samples instead of replaying byte-identical reports.
    let stashed_cache = world.cache.take();

    let mut rep_jobs: Vec<CiJob> = Vec::new();
    let mut extra_used = 0usize;
    let mut hist = History::new(&[policy.metric.as_str()]);
    let mut known = std::collections::BTreeSet::new();
    ingest_new_reports(&mut hist, &mut known, repo, &params.prefix);
    let evals = loop {
        let mut evals = evaluate(&hist, &policy, pipeline_id);
        // a series still without candidate data after a repetition round
        // ran the current definition is history the definition no longer
        // produces (e.g. a dropped node count) — not this pipeline's
        // evidence. First-round candidate-less *armed* series instead
        // request repetitions below: that is the cache-warm case, where
        // the replayed report deduped out of history.
        if extra_used > 0 {
            evals.retain(|e| e.classification.n_candidate > 0);
        }
        // how many more candidate samples does the neediest series want?
        // Unarmed (no-baseline) series never request repetitions: young
        // repositories pass for free (DESIGN.md §9 rule 1), warm or cold.
        let deficit = evals
            .iter()
            .filter(|e| e.classification.verdict != Verdict::NoBaseline)
            .map(|e| {
                policy
                    .min_repetitions
                    .saturating_sub(e.classification.n_candidate)
            })
            .max()
            .unwrap_or(0);
        let inconclusive = evals
            .iter()
            .any(|e| e.classification.verdict.wants_more_data());
        if deficit == 0 && !inconclusive {
            break evals;
        }
        let remaining = policy.max_extra_repetitions.saturating_sub(extra_used);
        if remaining == 0 {
            break evals;
        }
        // reach the adaptive minimum in one concurrent batch; past it,
        // refine an inconclusive interval two repetitions at a time
        let want = if deficit > 0 { deficit } else { 2 };
        let batch = want.min(remaining);
        job.log_line(format!(
            "scheduling {batch} extra repetition(s) ({} of {} used): {}",
            extra_used + batch,
            policy.max_extra_repetitions,
            if deficit > 0 {
                "below adaptive minimum"
            } else {
                "interval inconclusive"
            }
        ));
        if crate::obs::metrics_on() {
            crate::obs::count_app(&repo.name, crate::obs::Ctr::GateRounds, 1);
            crate::obs::count_app(&repo.name, crate::obs::Ctr::GateReps, batch as u64);
        }
        let round_start = world.batch.get(&params.machine).map(|b| b.now());
        rep_jobs.extend(run_repetitions(world, repo, &params, batch, rng.as_deref_mut()));
        if crate::obs::tracing() {
            // machine-local clock at the round's edges: deterministic
            // because this machine's job sequence is pinned across drivers
            let round_end = world.batch.get(&params.machine).map(|b| b.now());
            if let (Some(s), Some(e)) = (round_start, round_end) {
                crate::obs::trace::span(
                    &params.machine,
                    "gate-round",
                    s,
                    e,
                    crate::obs::trace::args(&[
                        ("pipeline", pipeline_id.to_string()),
                        ("repo", repo.name.clone()),
                        ("reps", batch.to_string()),
                    ]),
                );
            }
        }
        extra_used += batch;
        ingest_new_reports(&mut hist, &mut known, repo, &params.prefix);
    };

    world.cache = stashed_cache;

    // ---- verdict + regressions.json sidecar ---------------------------
    let overall = evals
        .iter()
        .map(|e| e.classification.verdict)
        .max()
        .unwrap_or(Verdict::NoBaseline);
    let mut series_json = Json::arr();
    for e in &evals {
        let c = &e.classification;
        series_json.push(
            Json::obj()
                .set("benchmark", e.benchmark.as_str())
                .set("system", e.system.as_str())
                .set("nodes", e.nodes)
                .set("metric", policy.metric.as_str())
                .set("verdict", c.verdict.as_str())
                .set("interval", interval_json(c))
                .set("rel_shift_pct", c.rel_shift_pct)
                .set("threshold_abs", c.threshold_abs)
                .set(
                    "baseline",
                    Json::obj()
                        .set("points", c.n_baseline)
                        .set("mean", c.mean_baseline)
                        .set("pipelines_from", e.baseline_pipelines.0)
                        .set("pipelines_to", e.baseline_pipelines.1),
                )
                .set(
                    "candidate",
                    Json::obj()
                        .set("points", c.n_candidate)
                        .set("mean", c.mean_candidate)
                        .set("commit", e.candidate_commit.as_str()),
                ),
        );
        job.log_line(format!(
            "{}@{} nodes={}: {} (shift {:+.2}%, {} baseline / {} candidate points)",
            e.benchmark,
            e.system,
            e.nodes,
            c.verdict.as_str(),
            c.rel_shift_pct,
            c.n_baseline,
            c.n_candidate
        ));
    }
    let verdict_str = if evals.is_empty() {
        "no-data"
    } else {
        overall.as_str()
    };
    let doc = Json::obj()
        .set("component", "regression-check@v1")
        .set("metric", policy.metric.as_str())
        .set("threshold_pct", policy.threshold_pct)
        .set("confidence", policy.confidence)
        .set("pipeline_id", pipeline_id)
        .set("commit", repo.commit.as_str())
        .set("extra_repetitions", extra_used)
        .set("repetition_budget", policy.max_extra_repetitions)
        .set("verdict", verdict_str)
        .set("series", series_json);
    job.add_artifact("regressions.json", &doc.pretty());
    job.output = Json::obj()
        .set("verdict", verdict_str)
        .set("extra_repetitions", extra_used);

    let failed = evals.is_empty() || overall.fails_gate();
    job.log_line(format!(
        "verdict: {verdict_str} ({extra_used} extra repetition(s) of {} budget) → {}",
        policy.max_extra_repetitions,
        if failed { "FAIL" } else { "pass" }
    ));
    job.state = if failed {
        CiJobState::Failed
    } else {
        CiJobState::Success
    };
    rep_jobs.push(job);
    rep_jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_resolves_defaults_and_bounds() {
        let p = GatePolicy::from_inputs(&Json::obj());
        assert_eq!(p.metric, "runtime");
        assert_eq!(p.threshold_pct, 5.0);
        assert!((p.confidence - 0.95).abs() < 1e-12);
        assert_eq!(p.min_repetitions, 4);
        assert_eq!(p.max_extra_repetitions, 6);
        assert_eq!(p.baseline_window, 10);
        assert_eq!(p.min_baseline, 4);

        let p = GatePolicy::from_inputs(
            &Json::obj()
                .set("metric", "tts")
                .set("threshold_pct", 0u64)
                .set("confidence_pct", 200u64)
                .set("min_repetitions", 1u64),
        );
        assert_eq!(p.metric, "tts");
        assert_eq!(p.threshold_pct, 1.0); // clamped up
        assert!((p.confidence - 0.99).abs() < 1e-12); // clamped down
        assert_eq!(p.min_repetitions, 2); // clamped up
    }

    #[test]
    fn gate_without_execution_inputs_fails_validation() {
        let mut world = World::new(1);
        let mut repo = BenchmarkRepo::new("empty");
        // machine is empty → runner preflight can never pass; but the
        // params parse, so the gate runs and reports no-data
        let jobs = run_regression_gate(&mut world, &mut repo, &Json::obj(), 1, None);
        let gate = jobs.last().unwrap();
        assert_eq!(gate.state, CiJobState::Failed);
        let doc = Json::parse(gate.artifact("regressions.json").unwrap()).unwrap();
        assert_eq!(doc.str_of("verdict"), Some("no-data"));
    }

    #[test]
    fn gate_restores_cache_after_repetitions() {
        let mut world = World::new(5);
        world.enable_cache();
        let mut repo = BenchmarkRepo::new("r");
        run_regression_gate(&mut world, &mut repo, &Json::obj(), 1, None);
        assert!(world.cache.is_some(), "stashed cache must be restored");
    }
}
