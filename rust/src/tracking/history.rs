//! Longitudinal series reconstruction over the report store
//! (DESIGN.md §9).
//!
//! History is rebuilt **only** from protocol reports recorded on the
//! `exacb.data` branch — the same read-side discipline as the
//! post-processing orchestrators (§3): never executor or scheduler
//! state. Each successful data entry contributes one point to the
//! series keyed by (benchmark, system, metric, nodes), carrying
//! per-commit provenance (the source commit and pipeline id from the
//! report's `reporter` section).
//!
//! Points are **digest-keyed**: the point identity is a hash of the
//! report *content* plus the entry index and metric name. Two
//! consequences, both tested:
//!
//! * ingestion order does not matter — any permutation of the same
//!   reports reconstructs the identical history;
//! * a cache-warm replay, which re-commits a byte-identical report
//!   document under a new store path, never creates a new history point
//!   (replays are evidence of nothing).

use std::collections::BTreeMap;

use crate::protocol::Report;
use crate::store::{DataStore, Snapshot};
use crate::util::timeutil::SimTime;
use crate::util::wide_hash;

/// Identity of one longitudinal series.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct SeriesKey {
    /// Store-path prefix segment, e.g. `jedi.logmap` (the execution
    /// component's `prefix` input).
    pub benchmark: String,
    /// The machine the experiment ran on (`experiment.system`).
    pub system: String,
    /// Metric name; `runtime` is always available.
    pub metric: String,
    /// Parameter-point node count: different scales are different series.
    pub nodes: u64,
}

/// One observation with provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct HistoryPoint {
    /// Content digest: report document ⊕ entry index ⊕ metric.
    pub digest: String,
    /// Experiment timestamp (series x-axis).
    pub time: SimTime,
    /// Pipeline that produced the report (monotonic — the gate uses it
    /// to split baseline from candidate).
    pub pipeline_id: u64,
    /// Source-tree commit of the benchmark repository at run time.
    pub commit: String,
    pub value: f64,
}

/// A reconstructed series, points in (time, pipeline, digest) order.
#[derive(Debug, Clone)]
pub struct Series {
    pub key: SeriesKey,
    pub points: Vec<HistoryPoint>,
}

impl Series {
    pub fn values(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.value).collect()
    }
}

/// All series reconstructed from a report store.
#[derive(Debug, Clone, Default)]
pub struct History {
    metrics: Vec<String>,
    series: BTreeMap<SeriesKey, BTreeMap<String, HistoryPoint>>,
}

impl History {
    pub fn new(metrics: &[&str]) -> History {
        History {
            metrics: metrics.iter().map(|m| m.to_string()).collect(),
            series: BTreeMap::new(),
        }
    }

    /// Ingest one protocol document under a benchmark name. Returns
    /// `false` (and ingests nothing) when the document does not parse —
    /// robustness against partial generation, counted by the caller.
    pub fn ingest(&mut self, benchmark: &str, document: &str) -> bool {
        let Ok(report) = Report::parse(document) else {
            return false;
        };
        self.ingest_parsed(benchmark, &wide_hash(document.as_bytes()), &report);
        true
    }

    /// Ingest one already-parsed report whose content digest was
    /// computed upstream — the [`Snapshot`] fast path (parse once at
    /// snapshot build, reuse everywhere). Point digests are derived from
    /// `doc_digest` exactly as [`History::ingest`] derives them, so both
    /// paths reconstruct byte-identical series (differentially tested).
    pub fn ingest_parsed(&mut self, benchmark: &str, doc_digest: &str, report: &Report) {
        let time = report.experiment.time().unwrap_or_default();
        for (idx, e) in report.data.iter().enumerate() {
            if !e.success {
                continue;
            }
            for metric in &self.metrics {
                let v = if metric == "runtime" {
                    Some(e.runtime)
                } else {
                    e.metric(metric)
                };
                let Some(v) = v else { continue };
                if !v.is_finite() {
                    continue;
                }
                let key = SeriesKey {
                    benchmark: benchmark.to_string(),
                    system: report.experiment.system.clone(),
                    metric: metric.clone(),
                    nodes: e.nodes,
                };
                let digest = wide_hash(format!("{doc_digest}|{idx}|{metric}").as_bytes());
                self.series.entry(key).or_default().insert(
                    digest.clone(),
                    HistoryPoint {
                        digest,
                        time,
                        pipeline_id: report.reporter.pipeline_id,
                        commit: report.reporter.commit.clone(),
                        value: v,
                    },
                );
            }
        }
    }

    /// Reconstruct history from every `report.json` under `prefix` on
    /// `branch` (the `exacb.data` read-side discipline). The benchmark
    /// name of each series is the first store-path segment. Returns the
    /// history and the count of unparseable documents skipped.
    ///
    /// This is the legacy full-walk path, retained as the executable
    /// differential reference for [`History::from_snapshot`] (like
    /// `drive_reference` in the event loop) — hot consumers read via
    /// the snapshot.
    pub fn from_store(
        store: &DataStore,
        branch: &str,
        prefix: &str,
        metrics: &[&str],
    ) -> (History, usize) {
        let mut h = History::new(metrics);
        let mut skipped = 0;
        for (path, content) in store.read_all_iter(branch, prefix) {
            if !path.ends_with("report.json") {
                continue;
            }
            let benchmark = path.split('/').next().unwrap_or("");
            if !h.ingest(benchmark, content) {
                skipped += 1;
            }
        }
        (h, skipped)
    }

    /// Reconstruct history from a [`Snapshot`] — same read discipline
    /// and same results as [`History::from_store`] (differentially
    /// tested byte-identical), but each document was parsed exactly
    /// once, at snapshot build time, instead of once per reader.
    pub fn from_snapshot(snap: &Snapshot, prefix: &str, metrics: &[&str]) -> (History, usize) {
        let mut h = History::new(metrics);
        let mut skipped = 0;
        for (path, digest) in snap.paths_under(prefix) {
            if !path.ends_with("report.json") {
                continue;
            }
            let benchmark = path.split('/').next().unwrap_or("");
            match snap.doc(digest).and_then(|d| d.report.as_ref()) {
                Some(report) => h.ingest_parsed(benchmark, digest, report),
                None => skipped += 1,
            }
        }
        (h, skipped)
    }

    /// Every series, keys sorted, points in (time, pipeline, digest)
    /// order — identical whatever order reports were ingested in.
    pub fn series(&self) -> Vec<Series> {
        self.series
            .iter()
            .map(|(key, pts)| {
                let mut points: Vec<HistoryPoint> = pts.values().cloned().collect();
                points.sort_by(|a, b| {
                    (a.time, a.pipeline_id, &a.digest).cmp(&(b.time, b.pipeline_id, &b.digest))
                });
                Series {
                    key: key.clone(),
                    points,
                }
            })
            .collect()
    }

    /// Points of one series (sorted), if present.
    pub fn get(&self, key: &SeriesKey) -> Option<Vec<HistoryPoint>> {
        self.series().into_iter().find(|s| &s.key == key).map(|s| s.points)
    }

    pub fn total_points(&self) -> usize {
        self.series.values().map(|pts| pts.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total_points() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{DataEntry, Experiment, Reporter};
    use crate::util::json::Json;

    fn report(
        system: &str,
        day: i64,
        pipeline: u64,
        commit: &str,
        entries: &[(u64, f64)],
    ) -> String {
        Report {
            reporter: Reporter {
                tool: "exacb".into(),
                tool_version: "0.1".into(),
                pipeline_id: pipeline,
                commit: commit.into(),
                system: system.into(),
                timestamp: SimTime::from_days(day).iso8601(),
                ..Default::default()
            },
            parameter: Json::obj(),
            experiment: Experiment {
                system: system.into(),
                timestamp: SimTime::from_days(day).iso8601(),
                ..Default::default()
            },
            data: entries
                .iter()
                .map(|&(nodes, runtime)| DataEntry {
                    success: true,
                    runtime,
                    nodes,
                    metrics: Json::obj().set("tts", runtime),
                    ..Default::default()
                })
                .collect(),
        }
        .to_document()
    }

    #[test]
    fn series_split_by_nodes_and_metric() {
        let mut h = History::new(&["runtime", "tts"]);
        assert!(h.ingest("jedi.app", &report("jedi", 1, 10, "c1", &[(1, 5.0), (4, 2.0)])));
        assert!(h.ingest("jedi.app", &report("jedi", 2, 11, "c1", &[(1, 5.1)])));
        let all = h.series();
        // (1 node, 4 nodes) x (runtime, tts)
        assert_eq!(all.len(), 4, "{:?}", all.iter().map(|s| &s.key).collect::<Vec<_>>());
        let one_node_runtime = h
            .get(&SeriesKey {
                benchmark: "jedi.app".into(),
                system: "jedi".into(),
                metric: "runtime".into(),
                nodes: 1,
            })
            .unwrap();
        assert_eq!(one_node_runtime.len(), 2);
        assert_eq!(one_node_runtime[0].value, 5.0);
        assert_eq!(one_node_runtime[1].value, 5.1);
        assert_eq!(one_node_runtime[0].commit, "c1");
        assert_eq!(one_node_runtime[0].pipeline_id, 10);
    }

    #[test]
    fn byte_identical_documents_dedupe() {
        // a cache-warm replay re-commits the same document: no new point
        let doc = report("jedi", 3, 42, "c9", &[(2, 7.5)]);
        let mut h = History::new(&["runtime"]);
        h.ingest("jedi.app", &doc);
        let n1 = h.total_points();
        h.ingest("jedi.app", &doc);
        assert_eq!(h.total_points(), n1);
    }

    #[test]
    fn garbage_documents_are_skipped() {
        let mut h = History::new(&["runtime"]);
        assert!(!h.ingest("x", "{not json"));
        assert!(h.is_empty());
    }

    #[test]
    fn failed_entries_contribute_nothing() {
        let mut r = Report::parse(&report("jedi", 1, 1, "c", &[(1, 9.0)])).unwrap();
        r.data[0].success = false;
        let mut h = History::new(&["runtime"]);
        h.ingest("b", &r.to_document());
        assert!(h.is_empty());
    }

    /// Satellite: digest-keyed history is order-independent — any
    /// permutation of the same documents reconstructs identical series.
    #[test]
    fn history_is_ingestion_order_independent() {
        use crate::prop_assert;
        use crate::util::prop::check;
        check("history independent of ingestion order", 40, |g| {
            let n = g.usize(1, 8);
            let docs: Vec<String> = (0..n)
                .map(|i| {
                    report(
                        if g.bool() { "jedi" } else { "jupiter" },
                        g.i64(0, 5),
                        g.u64(1, 50),
                        &format!("c{}", g.u64(0, 3)),
                        &[(g.u64(1, 4), g.f64(1.0, 100.0)), (1, i as f64 + 0.5)],
                    )
                })
                .collect();
            let mut forward = History::new(&["runtime", "tts"]);
            for d in &docs {
                forward.ingest("bench", d);
            }
            let mut shuffled = docs.clone();
            // deterministic permutation from the generator
            for i in (1..shuffled.len()).rev() {
                let j = g.usize(0, i);
                shuffled.swap(i, j);
            }
            let mut backward = History::new(&["runtime", "tts"]);
            for d in &shuffled {
                backward.ingest("bench", d);
            }
            let a = forward.series();
            let b = backward.series();
            prop_assert!(a.len() == b.len(), "series counts differ: {} vs {}", a.len(), b.len());
            for (sa, sb) in a.iter().zip(&b) {
                prop_assert!(sa.key == sb.key, "keys diverge: {:?} vs {:?}", sa.key, sb.key);
                prop_assert!(
                    sa.points == sb.points,
                    "points diverge for {:?}",
                    sa.key
                );
            }
            Ok(())
        });
    }

    #[test]
    fn from_store_reads_only_reports() {
        let mut store = DataStore::new();
        store.commit(
            "exacb.data",
            &[
                ("jedi.app/1/report.json".into(), report("jedi", 1, 1, "c", &[(1, 4.0)])),
                ("jedi.app/1/results.csv".into(), "a,b\n1,2\n".into()),
                ("jedi.app/2/report.json".into(), "{broken".into()),
            ],
            "m",
            SimTime(0),
        );
        let (h, skipped) = History::from_store(&store, "exacb.data", "jedi.app/", &["runtime"]);
        assert_eq!(h.total_points(), 1);
        assert_eq!(skipped, 1);
        assert_eq!(h.series()[0].key.benchmark, "jedi.app");
        // the snapshot path reconstructs the identical history,
        // including the skipped-document count
        let snap = Snapshot::build(&store, "exacb.data");
        let (hs, skipped_s) = History::from_snapshot(&snap, "jedi.app/", &["runtime"]);
        assert_eq!(skipped_s, skipped);
        let (a, b) = (h.series(), hs.series());
        assert_eq!(a.len(), b.len());
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.key, sb.key);
            assert_eq!(sa.points, sb.points);
        }
    }
}
