//! Regression-decision statistics (cbdr-style, DESIGN.md §9).
//!
//! CI gating on noisy measurements must not compare point estimates:
//! "Continuous Benchmarking, Done Right" gates on a **confidence
//! interval on the difference of means**, resampling until the interval
//! is narrow enough to decide. This module provides that machinery with
//! zero external dependencies: Welch's t interval (unequal variances,
//! Welch–Satterthwaite degrees of freedom, an in-repo inverse-t
//! quantile) and a seeded percentile bootstrap on [`crate::util::prng`].
//!
//! Conventions: intervals are on `mean(after) - mean(before)` in the
//! metric's own units. The gate's decision "interval lower bound above
//! +threshold" is a one-tailed test at level `(1 - confidence) / 2`.

use crate::util::prng::Prng;

/// A two-sided confidence interval on the difference of means.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConfInterval {
    pub lo: f64,
    pub hi: f64,
    /// Two-sided confidence level in (0, 1), e.g. 0.95.
    pub confidence: f64,
}

impl ConfInterval {
    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }

    pub fn contains(&self, x: f64) -> bool {
        self.lo <= x && x <= self.hi
    }

    /// The whole interval sits above `x` (one-tailed significance).
    pub fn entirely_above(&self, x: f64) -> bool {
        self.lo > x
    }

    /// The whole interval sits below `x`.
    pub fn entirely_below(&self, x: f64) -> bool {
        self.hi < x
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (n-1 denominator); 0 for n < 2.
pub fn sample_var(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Inverse standard-normal CDF (Acklam's rational approximation,
/// |relative error| < 1.15e-9 over (0, 1)).
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "normal_quantile needs p in (0,1), got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Inverse Student-t CDF via the Cornish–Fisher expansion in the normal
/// quantile (accurate to ~0.5% down to df = 2; exact as df → ∞).
pub fn t_quantile(df: f64, p: f64) -> f64 {
    let df = df.max(1.0);
    let z = normal_quantile(p);
    if df > 1e6 {
        return z;
    }
    let z2 = z * z;
    let z3 = z2 * z;
    let z5 = z3 * z2;
    let z7 = z5 * z2;
    let z9 = z7 * z2;
    let g1 = (z3 + z) / 4.0;
    let g2 = (5.0 * z5 + 16.0 * z3 + 3.0 * z) / 96.0;
    let g3 = (3.0 * z7 + 19.0 * z5 + 17.0 * z3 - 15.0 * z) / 384.0;
    let g4 = (79.0 * z9 + 776.0 * z7 + 1482.0 * z5 - 1920.0 * z3 - 945.0 * z) / 92160.0;
    z + g1 / df + g2 / (df * df) + g3 / (df * df * df) + g4 / (df * df * df * df)
}

/// Welch–Satterthwaite effective degrees of freedom.
fn welch_df(v1: f64, n1: f64, v2: f64, n2: f64) -> f64 {
    let a = v1 / n1;
    let b = v2 / n2;
    let denom = a * a / (n1 - 1.0) + b * b / (n2 - 1.0);
    if denom <= 0.0 {
        return f64::MAX;
    }
    ((a + b) * (a + b)) / denom
}

/// Welch's t confidence interval on `mean(after) - mean(before)`.
/// Needs at least 2 samples on each side; `confidence` is the two-sided
/// level (the gate reads one tail at `(1 - confidence) / 2`).
pub fn welch_interval(before: &[f64], after: &[f64], confidence: f64) -> Option<ConfInterval> {
    if before.len() < 2 || after.len() < 2 {
        return None;
    }
    let confidence = confidence.clamp(0.5, 0.9999);
    let (n1, n2) = (before.len() as f64, after.len() as f64);
    let (v1, v2) = (sample_var(before), sample_var(after));
    let d = mean(after) - mean(before);
    let se = (v1 / n1 + v2 / n2).sqrt();
    if se <= 0.0 {
        // both samples are exactly constant: the difference is certain
        return Some(ConfInterval {
            lo: d,
            hi: d,
            confidence,
        });
    }
    // floor df at 2: the Cornish–Fisher inverse-t is only accurate down
    // to df ≈ 2 (at df = 1 it is ~10% narrow at 95%), and 2-vs-2-sample
    // comparisons with very unequal variances push Welch–Satterthwaite
    // below that. Flooring widens the interval — conservative for a
    // gate: the verdict degrades to inconclusive, never to a false fail.
    let df = welch_df(v1, n1, v2, n2).max(2.0);
    let t = t_quantile(df, 0.5 + confidence / 2.0);
    Some(ConfInterval {
        lo: d - t * se,
        hi: d + t * se,
        confidence,
    })
}

/// Seeded percentile bootstrap interval on `mean(after) - mean(before)`.
/// Deterministic for a given seed (the PRNG substrate, DESIGN.md §2);
/// `reps` resamples, both sides resampled with replacement.
pub fn bootstrap_interval(
    before: &[f64],
    after: &[f64],
    confidence: f64,
    reps: usize,
    seed: u64,
) -> Option<ConfInterval> {
    if before.is_empty() || after.is_empty() || reps < 8 {
        return None;
    }
    let confidence = confidence.clamp(0.5, 0.9999);
    let mut rng = Prng::new(seed);
    let mut diffs = Vec::with_capacity(reps);
    let resampled_mean = |xs: &[f64], rng: &mut Prng| -> f64 {
        let mut s = 0.0;
        for _ in 0..xs.len() {
            s += xs[rng.below(xs.len() as u64) as usize];
        }
        s / xs.len() as f64
    };
    for _ in 0..reps {
        let mb = resampled_mean(before, &mut rng);
        let ma = resampled_mean(after, &mut rng);
        diffs.push(ma - mb);
    }
    let alpha = 1.0 - confidence;
    Some(ConfInterval {
        lo: crate::util::stats::percentile(&diffs, 100.0 * alpha / 2.0),
        hi: crate::util::stats::percentile(&diffs, 100.0 * (1.0 - alpha / 2.0)),
        confidence,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn normal_quantile_known_values() {
        assert!((normal_quantile(0.975) - 1.959964).abs() < 1e-4);
        assert!((normal_quantile(0.5)).abs() < 1e-9);
        assert!((normal_quantile(0.025) + 1.959964).abs() < 1e-4);
        assert!((normal_quantile(0.95) - 1.644854).abs() < 1e-4);
    }

    #[test]
    fn t_quantile_matches_tables() {
        // textbook two-sided 95% critical values
        assert!((t_quantile(10.0, 0.975) - 2.228).abs() < 0.01);
        assert!((t_quantile(4.0, 0.975) - 2.776).abs() < 0.02);
        assert!((t_quantile(30.0, 0.975) - 2.042).abs() < 0.005);
        assert!((t_quantile(1e9, 0.975) - 1.96).abs() < 0.001);
        // symmetry
        assert!((t_quantile(7.0, 0.975) + t_quantile(7.0, 0.025)).abs() < 1e-9);
    }

    #[test]
    fn welch_interval_brackets_obvious_shift() {
        let before = [10.0, 10.1, 9.9, 10.05, 9.95];
        let after = [12.0, 12.1, 11.9, 12.05, 11.95];
        let ci = welch_interval(&before, &after, 0.95).unwrap();
        assert!(ci.contains(2.0), "{ci:?}");
        assert!(ci.entirely_above(1.0), "{ci:?}");
        assert!(ci.lo > 1.5 && ci.hi < 2.5, "{ci:?}");
    }

    #[test]
    fn welch_interval_needs_two_samples() {
        assert!(welch_interval(&[1.0], &[2.0, 3.0], 0.95).is_none());
        assert!(welch_interval(&[1.0, 2.0], &[3.0], 0.95).is_none());
    }

    #[test]
    fn welch_interval_constant_samples() {
        let ci = welch_interval(&[5.0, 5.0, 5.0], &[7.0, 7.0], 0.95).unwrap();
        assert_eq!((ci.lo, ci.hi), (2.0, 2.0));
    }

    #[test]
    fn welch_floors_df_at_two() {
        // 2-vs-2 with extreme variance imbalance drives Welch df toward
        // 1; the interval must be built from the (floored) df = 2
        // critical value, not the underestimating df = 1 expansion
        let before = [0.0, 0.002];
        let after = [10.0, 14.0];
        let ci = welch_interval(&before, &after, 0.95).unwrap();
        let d = mean(&after) - mean(&before);
        let se = (sample_var(&before) / 2.0 + sample_var(&after) / 2.0).sqrt();
        let expected_half = t_quantile(2.0, 0.975) * se;
        assert!(
            ((ci.hi - d) - expected_half).abs() < 1e-9,
            "half-width {} vs floored-df {}",
            ci.hi - d,
            expected_half
        );
    }

    #[test]
    fn welch_interval_negates_under_swap() {
        let a = [10.0, 10.4, 9.8, 10.2];
        let b = [11.0, 11.3, 10.9, 11.2, 11.1];
        let ab = welch_interval(&a, &b, 0.95).unwrap();
        let ba = welch_interval(&b, &a, 0.95).unwrap();
        assert!((ab.lo + ba.hi).abs() < 1e-12, "{ab:?} {ba:?}");
        assert!((ab.hi + ba.lo).abs() < 1e-12);
    }

    #[test]
    fn bootstrap_is_seed_deterministic() {
        let a = [10.0, 11.0, 9.5, 10.5, 10.2, 9.8];
        let b = [12.0, 12.5, 11.5, 12.2, 11.8, 12.1];
        let c1 = bootstrap_interval(&a, &b, 0.9, 300, 42).unwrap();
        let c2 = bootstrap_interval(&a, &b, 0.9, 300, 42).unwrap();
        assert_eq!(c1, c2);
        let c3 = bootstrap_interval(&a, &b, 0.9, 300, 43).unwrap();
        assert!(c1 != c3, "different seeds should resample differently");
        assert!(c1.contains(2.0) || c1.width() < 1.0, "{c1:?}");
    }

    /// Satellite: the Welch CI covers the true mean difference at
    /// (approximately) the nominal rate under the seeded PRNG. 90%
    /// nominal over 300 trials has a binomial sd of ~1.7%, so the
    /// [0.84, 0.97] acceptance band is ~3.5 sd wide.
    #[test]
    fn welch_coverage_is_nominal() {
        let mut rng = Prng::new(20260730);
        let true_diff = 3.0;
        let trials = 300;
        let mut covered = 0;
        for _ in 0..trials {
            let before: Vec<f64> = (0..8).map(|_| rng.normal(10.0, 1.0)).collect();
            let after: Vec<f64> = (0..8).map(|_| rng.normal(10.0 + true_diff, 1.0)).collect();
            let ci = welch_interval(&before, &after, 0.90).unwrap();
            if ci.contains(true_diff) {
                covered += 1;
            }
        }
        let rate = covered as f64 / trials as f64;
        assert!(
            (0.84..=0.97).contains(&rate),
            "coverage {rate} far from nominal 0.90"
        );
    }

    #[test]
    fn bootstrap_coverage_is_roughly_nominal() {
        let mut rng = Prng::new(99);
        let true_diff = 2.0;
        let trials: u64 = 150;
        let mut covered = 0;
        for t in 0..trials {
            let before: Vec<f64> = (0..12).map(|_| rng.normal(20.0, 1.5)).collect();
            let after: Vec<f64> = (0..12).map(|_| rng.normal(22.0, 1.5)).collect();
            let ci = bootstrap_interval(&before, &after, 0.90, 200, 1000 + t).unwrap();
            if ci.contains(true_diff) {
                covered += 1;
            }
        }
        let rate = covered as f64 / trials as f64;
        // percentile bootstrap under-covers slightly at small n
        assert!(
            (0.78..=0.98).contains(&rate),
            "bootstrap coverage {rate} implausible for nominal 0.90"
        );
    }

    #[test]
    fn welch_coverage_property_over_random_shapes() {
        check("welch CI covers true diff for zero-variance-free draws", 40, |g| {
            let n1 = g.usize(4, 12);
            let n2 = g.usize(4, 12);
            let diff = g.f64(-5.0, 5.0);
            let seed = g.u64(0, u64::MAX / 2);
            // average coverage over repeated draws at this shape: a single
            // 95% interval can legitimately miss, so check the rate
            let mut rng = Prng::new(seed);
            let mut covered = 0;
            let reps = 60;
            for _ in 0..reps {
                let before: Vec<f64> = (0..n1).map(|_| rng.normal(50.0, 2.0)).collect();
                let after: Vec<f64> = (0..n2).map(|_| rng.normal(50.0 + diff, 2.0)).collect();
                if welch_interval(&before, &after, 0.95).unwrap().contains(diff) {
                    covered += 1;
                }
            }
            // 95% nominal, 60 reps: p(<44 covered) is astronomically small
            prop_assert!(
                covered >= 44,
                "coverage {covered}/60 at n1={n1} n2={n2} diff={diff}"
            );
            Ok(())
        });
    }
}
