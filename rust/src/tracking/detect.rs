//! Verdict classification over performance series (DESIGN.md §9).
//!
//! Two consumers, two granularities:
//!
//! * [`Detector::classify`] — the gate's decision: Welch CI on the
//!   difference of means between a baseline sample and a candidate
//!   sample, thresholded symmetrically so before/after swap exactly
//!   exchanges improvement and regression (property-tested).
//! * [`Detector::annotate`] + [`segment`] — longitudinal scanning: each
//!   point judged against a rolling baseline window (prediction-interval
//!   rule), plus binary-segmentation change-point detection over the
//!   whole series via [`crate::util::stats::changepoints`].
//!
//! Metrics are treated as **lower-is-better** (runtime, energy): a mean
//! shift up is a regression. Higher-is-better metrics (bandwidths) can
//! be gated by negating the series at the call site.

use super::stats::{mean, normal_quantile, sample_var, welch_interval, ConfInterval};
use crate::util::stats::{changepoints, Changepoint};

/// Outcome of comparing a candidate sample against a baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verdict {
    /// Not enough baseline history to judge at all.
    NoBaseline,
    /// The interval lies inside the ±threshold band: no change to act on.
    Stable,
    /// Statistically significant shift *down* (faster / cheaper).
    Improvement,
    /// The interval straddles a threshold boundary: measure more.
    Inconclusive,
    /// Statistically significant shift *up* beyond the threshold.
    Regression,
}

impl Verdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            Verdict::NoBaseline => "no-baseline",
            Verdict::Stable => "stable",
            Verdict::Improvement => "improvement",
            Verdict::Inconclusive => "inconclusive",
            Verdict::Regression => "regression",
        }
    }

    /// Should a CI gate fail on this verdict (after the repetition
    /// budget is exhausted)? Regressions always; inconclusive too — a
    /// gate that cannot prove "no regression" within budget must not
    /// pass silently (the cbdr stance).
    pub fn fails_gate(&self) -> bool {
        matches!(self, Verdict::Regression | Verdict::Inconclusive)
    }

    /// True when more repetitions could still change the verdict.
    pub fn wants_more_data(&self) -> bool {
        matches!(self, Verdict::Inconclusive)
    }
}

/// One classification with its evidence.
#[derive(Debug, Clone)]
pub struct Classification {
    pub verdict: Verdict,
    /// Welch CI on `mean(candidate) - mean(baseline)` (absolute units);
    /// `None` when either side has fewer than 2 samples.
    pub interval: Option<ConfInterval>,
    /// Relative shift in percent of the baseline mean.
    pub rel_shift_pct: f64,
    /// The absolute threshold the interval was compared against.
    pub threshold_abs: f64,
    pub mean_baseline: f64,
    pub mean_candidate: f64,
    pub n_baseline: usize,
    pub n_candidate: usize,
}

/// Detection policy: confidence level and the practical-significance
/// threshold (shifts smaller than `threshold_pct` are noise by decree,
/// whatever their p-value).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Detector {
    /// Two-sided confidence for the Welch interval, e.g. 0.95.
    pub confidence: f64,
    /// Practical-significance threshold in percent.
    pub threshold_pct: f64,
}

impl Default for Detector {
    fn default() -> Self {
        Detector {
            confidence: 0.95,
            threshold_pct: 5.0,
        }
    }
}

impl Detector {
    /// Classify a candidate sample against a baseline sample.
    ///
    /// The absolute threshold is `threshold_pct` of the *symmetric*
    /// scale `(|mean_b| + |mean_c|) / 2`, so swapping the two samples
    /// negates the interval against an identical threshold: regression
    /// and improvement exchange exactly, stable and inconclusive are
    /// fixed points (property-tested).
    pub fn classify(&self, baseline: &[f64], candidate: &[f64]) -> Classification {
        let mb = mean(baseline);
        let mc = mean(candidate);
        // |mb| in the denominator: on negated (higher-is-better) series a
        // regression must still read as a positive shift. Empty sides
        // have NaN means; guard so the evidence fields stay meaningful.
        let rel = if mb.is_finite() && mc.is_finite() && mb.abs() > 1e-300 {
            100.0 * (mc - mb) / mb.abs()
        } else {
            0.0
        };
        let thr = {
            let (mut scale, mut n) = (0.0, 0.0);
            for m in [mb, mc] {
                if m.is_finite() {
                    scale += m.abs();
                    n += 1.0;
                }
            }
            self.threshold_pct / 100.0 * if n > 0.0 { scale / n } else { 0.0 }
        };
        let (verdict, interval) = if baseline.len() < 2 {
            (Verdict::NoBaseline, None)
        } else if candidate.len() < 2 {
            (Verdict::Inconclusive, None)
        } else {
            let ci = welch_interval(baseline, candidate, self.confidence)
                .expect("both sides have >= 2 samples");
            let v = if ci.entirely_above(thr) {
                Verdict::Regression
            } else if ci.entirely_below(-thr) {
                Verdict::Improvement
            } else if ci.lo >= -thr && ci.hi <= thr {
                Verdict::Stable
            } else {
                Verdict::Inconclusive
            };
            (v, Some(ci))
        };
        Classification {
            verdict,
            interval,
            rel_shift_pct: rel,
            threshold_abs: thr,
            mean_baseline: mb,
            mean_candidate: mc,
            n_baseline: baseline.len(),
            n_candidate: candidate.len(),
        }
    }

    /// Judge a single observation against a rolling baseline: outside
    /// the prediction interval *and* beyond the practical threshold is a
    /// shift. Used by [`Detector::annotate`]; the gate uses the stronger
    /// sample-vs-sample [`Detector::classify`].
    pub fn classify_point(&self, baseline: &[f64], x: f64) -> Verdict {
        if baseline.len() < 3 {
            return Verdict::NoBaseline;
        }
        let m = mean(baseline);
        let sd = sample_var(baseline).sqrt();
        let z = normal_quantile(0.5 + self.confidence / 2.0);
        let margin = (z * sd * (1.0 + 1.0 / baseline.len() as f64).sqrt())
            .max(self.threshold_pct / 100.0 * m.abs());
        if x > m + margin {
            Verdict::Regression
        } else if x < m - margin {
            Verdict::Improvement
        } else {
            Verdict::Stable
        }
    }

    /// Per-point verdicts over a whole series: point `i` is judged
    /// against the `window` points preceding it.
    pub fn annotate(&self, values: &[f64], window: usize) -> Vec<Verdict> {
        let window = window.max(1);
        values
            .iter()
            .enumerate()
            .map(|(i, &x)| {
                let lo = i.saturating_sub(window);
                self.classify_point(&values[lo..i], x)
            })
            .collect()
    }
}

/// Change-point segmentation over a whole series (binary segmentation,
/// [`crate::util::stats::changepoints`]) with shifts labelled by
/// direction for lower-is-better metrics.
pub fn segment(values: &[f64], threshold_sd: f64) -> Vec<(Changepoint, Verdict)> {
    changepoints(values, threshold_sd)
        .into_iter()
        .map(|cp| {
            let v = if cp.after > cp.before {
                Verdict::Regression
            } else {
                Verdict::Improvement
            };
            (cp, v)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Prng;
    use crate::util::prop::check;

    fn det() -> Detector {
        Detector::default()
    }

    #[test]
    fn clear_regression_and_improvement() {
        let base = [10.0, 10.1, 9.9, 10.05, 9.95, 10.02];
        let slow = [12.0, 12.1, 11.9];
        let fast = [8.0, 8.1, 7.9];
        assert_eq!(det().classify(&base, &slow).verdict, Verdict::Regression);
        assert_eq!(det().classify(&base, &fast).verdict, Verdict::Improvement);
        let c = det().classify(&base, &slow);
        assert!(c.rel_shift_pct > 15.0, "{c:?}");
        assert!(c.interval.unwrap().lo > c.threshold_abs);
    }

    #[test]
    fn tiny_shift_is_stable() {
        let base = [10.0, 10.02, 9.98, 10.01, 9.99, 10.0, 10.01];
        let cand = [10.05, 10.06, 10.04, 10.05];
        assert_eq!(det().classify(&base, &cand).verdict, Verdict::Stable);
    }

    #[test]
    fn short_sides_are_flagged() {
        assert_eq!(det().classify(&[1.0], &[2.0, 3.0]).verdict, Verdict::NoBaseline);
        assert_eq!(
            det().classify(&[1.0, 1.1, 0.9], &[2.0]).verdict,
            Verdict::Inconclusive
        );
    }

    #[test]
    fn borderline_shift_is_inconclusive() {
        // a noisy shift right at the threshold: interval straddles it
        let base = [10.0, 10.8, 9.2, 10.5, 9.5];
        let cand = [10.6, 11.4, 9.8, 11.0];
        let c = det().classify(&base, &cand);
        assert_eq!(c.verdict, Verdict::Inconclusive, "{c:?}");
        assert!(c.verdict.wants_more_data());
    }

    /// Satellite: verdicts are antisymmetric under before/after swap.
    #[test]
    fn verdicts_antisymmetric_under_swap() {
        check("classify(a,b) mirrors classify(b,a)", 120, |g| {
            let seed = g.u64(0, u64::MAX / 2);
            let n1 = g.usize(2, 10);
            let n2 = g.usize(2, 10);
            let shift = g.f64(-3.0, 3.0);
            let sd = g.f64(0.01, 1.5).max(0.01);
            let mut rng = Prng::new(seed);
            let a: Vec<f64> = (0..n1).map(|_| rng.normal(20.0, sd)).collect();
            let b: Vec<f64> = (0..n2).map(|_| rng.normal(20.0 + shift, sd)).collect();
            let d = det();
            let ab = d.classify(&a, &b).verdict;
            let ba = d.classify(&b, &a).verdict;
            let mirrored = match ab {
                Verdict::Regression => Verdict::Improvement,
                Verdict::Improvement => Verdict::Regression,
                v => v,
            };
            prop_assert!(
                ba == mirrored,
                "classify(a,b)={ab:?} but classify(b,a)={ba:?} (n1={n1} n2={n2} shift={shift} sd={sd})"
            );
            Ok(())
        });
    }

    #[test]
    fn annotate_flags_the_step() {
        let mut xs: Vec<f64> = (0..20).map(|i| 10.0 + (i % 3) as f64 * 0.02).collect();
        xs.extend((0..10).map(|i| 13.0 + (i % 3) as f64 * 0.02));
        let verdicts = det().annotate(&xs, 10);
        assert_eq!(verdicts.len(), 30);
        assert_eq!(verdicts[20], Verdict::Regression);
        // early points have no baseline
        assert_eq!(verdicts[0], Verdict::NoBaseline);
        // steady-state points are stable
        assert_eq!(verdicts[15], Verdict::Stable);
    }

    #[test]
    fn segment_labels_direction() {
        let mut xs = vec![];
        for i in 0..40 {
            xs.push(10.0 + (i % 4) as f64 * 0.01);
        }
        for i in 0..40 {
            xs.push(12.0 + (i % 4) as f64 * 0.01);
        }
        let segs = segment(&xs, 5.0);
        assert!(!segs.is_empty());
        assert!(segs.iter().any(|(cp, v)| {
            *v == Verdict::Regression && (36..=44).contains(&cp.index)
        }));
    }

    #[test]
    fn verdict_gate_policy() {
        assert!(Verdict::Regression.fails_gate());
        assert!(Verdict::Inconclusive.fails_gate());
        assert!(!Verdict::Stable.fails_gate());
        assert!(!Verdict::Improvement.fails_gate());
        assert!(!Verdict::NoBaseline.fails_gate());
    }
}
