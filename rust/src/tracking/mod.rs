//! Statistical regression detection and longitudinal performance
//! tracking over the report store (DESIGN.md §9).
//!
//! The paper's promise for continuous benchmarking is "early detection
//! of regressions" and performance tracking across the software
//! lifecycle; this top-layer module is the decision side of that loop.
//! It consumes **only** recorded protocol reports (the `exacb.data`
//! read-side discipline, §3) and produces verdicts:
//!
//! * [`history`] — digest-keyed per-(benchmark, system, metric, nodes)
//!   series reconstruction with per-commit provenance;
//! * [`stats`] — Welch's t confidence intervals on the difference of
//!   means + a seeded bootstrap (no external dependencies);
//! * [`detect`] — improvement / stable / inconclusive / regression
//!   classification against a rolling baseline, plus change-point
//!   segmentation over whole series;
//! * [`gate`] — the `regression-check@v1` CI component: adaptive
//!   repetition scheduling through the discrete-event core and the
//!   pass/fail policy with its `regressions.json` sidecar artifact.
//!
//! Like `analysis`, this module is invoked from the coordinator's
//! component dispatch; [`track_table`] and
//! [`crate::coordinator::World::track_table`] are the a-posteriori
//! entry points behind `exacb track`.

pub mod detect;
pub mod gate;
pub mod history;
pub mod stats;

pub use detect::{segment, Classification, Detector, Verdict};
pub use gate::{run_regression_gate, GatePolicy};
pub use history::{History, HistoryPoint, Series, SeriesKey};
pub use stats::{bootstrap_interval, welch_interval, ConfInterval};

use crate::ci::Trigger;
use crate::coordinator::{BenchmarkRepo, World};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::timeutil::SimTime;
use crate::workloads::regression::RegressionScenario;

/// Longitudinal verdict table across every repository in the world:
/// one row per reconstructed series with its latest rolling-baseline
/// verdict and change-point count. Labelled empty row when nothing has
/// been recorded yet.
pub fn track_table(world: &World, metric: &str, det: &Detector) -> Table {
    let mut t = Table::new(&[
        "benchmark",
        "system",
        "nodes",
        "metric",
        "points",
        "latest",
        "changepoints",
    ]);
    // nodes stays numeric until after the sort so scaling series render
    // as 1, 2, 4, 8, 16 — not lexicographically
    let mut rows: Vec<(String, String, u64, String, usize, String, usize)> = Vec::new();
    for repo in world.repos.values() {
        // read via the repo's shared snapshot (DESIGN.md §12): the
        // table pays O(delta since last reader), not a full re-walk
        let (hist, _) = repo.with_snapshot(|snap| History::from_snapshot(snap, "", &[metric]));
        for s in hist.series() {
            let values = s.values();
            let verdicts = det.annotate(&values, 10);
            let cps = crate::util::stats::changepoints(&values, 5.0);
            rows.push((
                s.key.benchmark.clone(),
                s.key.system.clone(),
                s.key.nodes,
                s.key.metric.clone(),
                values.len(),
                verdicts
                    .last()
                    .map(|v| v.as_str())
                    .unwrap_or("-")
                    .to_string(),
                cps.len(),
            ));
        }
    }
    rows.sort();
    rows.dedup();
    if rows.is_empty() {
        t.push_placeholder("(no recorded reports)");
    } else {
        for (benchmark, system, nodes, metric, points, latest, cps) in rows {
            t.push_row(vec![
                benchmark,
                system,
                nodes.to_string(),
                metric,
                points.to_string(),
                latest,
                cps.to_string(),
            ]);
        }
    }
    t
}

/// What one scenario campaign produced, day by day.
#[derive(Debug, Clone, Default)]
pub struct ScenarioOutcome {
    /// (day, pipeline id, pipeline succeeded).
    pub pipelines: Vec<(i64, u64, bool)>,
    /// Days whose pipeline failed (the gate, or anything else).
    pub failed_days: Vec<i64>,
    /// (day, gate verdict, extra repetitions used) per day the gate ran.
    pub gate_by_day: Vec<(i64, String, u64)>,
}

impl ScenarioOutcome {
    pub fn first_failed_day(&self) -> Option<i64> {
        self.failed_days.first().copied()
    }

    pub fn extra_reps_on(&self, day: i64) -> Option<u64> {
        self.gate_by_day
            .iter()
            .find(|(d, _, _)| *d == day)
            .map(|(_, _, e)| *e)
    }

    pub fn verdict_on(&self, day: i64) -> Option<&str> {
        self.gate_by_day
            .iter()
            .find(|(d, _, _)| *d == day)
            .map(|(_, v, _)| v.as_str())
    }
}

/// Drive a seeded injected-regression scenario end to end: onboard the
/// scenario repository (execution + regression gate in its CI config),
/// fire its daily scheduled pipeline, and apply the planted source
/// change on the injection day (the jube command slows down and the
/// repository commit moves — exactly what a real regressing merge
/// looks like to the framework).
pub fn run_scenario(world: &mut World, sc: &RegressionScenario) -> ScenarioOutcome {
    world.add_repo(
        BenchmarkRepo::new(&sc.app)
            .with_file("benchmark/jube/app.yml", &sc.jube_file(0))
            .with_file(".gitlab-ci.yml", &sc.ci_file()),
    );
    let mut out = ScenarioOutcome::default();
    for day in 0..sc.days {
        world.advance_to(SimTime::from_days(day).add_secs(3 * 3600));
        // apply the day's source state; a changed definition is a commit
        let desired = sc.jube_file(day);
        if let Some(repo) = world.repos.get_mut(&sc.app) {
            let current = repo.file("benchmark/jube/app.yml").map(str::to_string);
            if current.as_deref() != Some(desired.as_str()) {
                for (path, content) in repo.files.iter_mut() {
                    if path == "benchmark/jube/app.yml" {
                        *content = desired.clone();
                    }
                }
                repo.commit =
                    crate::util::short_hash(format!("{desired}|day{day}").as_bytes());
            }
        }
        match world.run_pipeline(&sc.app, Trigger::Scheduled) {
            Ok(pid) => {
                let ok = world
                    .pipeline(pid)
                    .map(|p| p.succeeded())
                    .unwrap_or(false);
                out.pipelines.push((day, pid, ok));
                if !ok {
                    out.failed_days.push(day);
                }
                if let Some(p) = world.pipeline(pid) {
                    if let Some(j) = p
                        .jobs
                        .iter()
                        .find(|j| j.name.ends_with(".regression-check"))
                    {
                        if let Some(doc) = j.artifact("regressions.json") {
                            if let Ok(v) = Json::parse(doc) {
                                out.gate_by_day.push((
                                    day,
                                    v.str_of("verdict").unwrap_or("?").to_string(),
                                    v.u64_of("extra_repetitions").unwrap_or(0),
                                ));
                            }
                        }
                    }
                }
            }
            Err(_) => {
                out.pipelines.push((day, 0, false));
                out.failed_days.push(day);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn track_table_labels_empty_world() {
        let world = World::new(1);
        let t = track_table(&world, "runtime", &Detector::default());
        assert_eq!(t.rows.len(), 1);
        assert!(t.rows[0][0].contains("no recorded reports"), "{:?}", t.rows);
    }

    /// §11 wiring: the jpwr launcher's `energy_j`/`edp` metrics land in
    /// recorded reports like any other metric, so longitudinal tracking
    /// — and therefore the regression gate — can run on them unchanged.
    #[test]
    fn track_table_tracks_energy_metrics() {
        let mut world = World::new(11);
        let jube = "name: eapp\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: 1\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name eapp --flops 100000 --membound 0.4 --steps 30\n";
        let ci = "include:\n  - component: execution@v3\n    inputs:\n      prefix: \"jedi.eapp\"\n      machine: \"jedi\"\n      queue: \"all\"\n      project: \"cjsc\"\n      budget: \"zam\"\n      jube_file: \"b.yml\"\n      launcher: \"jpwr\"\n";
        world.add_repo(
            BenchmarkRepo::new("eapp")
                .with_file("b.yml", jube)
                .with_file(".gitlab-ci.yml", ci),
        );
        for d in 0..3 {
            world.advance_to(SimTime::from_days(d).add_secs(3 * 3600));
            world.run_pipeline("eapp", Trigger::Scheduled).unwrap();
        }
        for metric in ["energy_j", "edp"] {
            let t = world.track_table(metric);
            assert_eq!(t.rows.len(), 1, "{metric}: {:?}", t.rows);
            assert_eq!(t.rows[0][0], "jedi.eapp");
            assert_eq!(t.rows[0][3], metric);
            assert_eq!(t.rows[0][4], "3", "{metric}: {:?}", t.rows);
        }
        // and through History directly: finite, positive series
        let repo = world.repo("eapp").unwrap();
        let (h, _) =
            History::from_store(&repo.store, "exacb.data", "", &["energy_j", "edp"]);
        assert_eq!(h.total_points(), 6);
        for s in h.series() {
            for p in &s.points {
                assert!(p.value.is_finite() && p.value > 0.0, "{:?}", s.key);
            }
        }
    }

    #[test]
    fn track_table_over_recorded_history() {
        let mut world = World::new(7);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        for d in 0..6 {
            world.advance_to(SimTime::from_days(d).add_secs(3 * 3600));
            world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
        }
        let t = world.track_table("runtime");
        assert_eq!(t.rows.len(), 1, "{:?}", t.rows);
        assert_eq!(t.rows[0][0], "jedi.logmap");
        assert_eq!(t.rows[0][1], "jedi");
        assert_eq!(t.rows[0][4], "6");
        // a steady series settles to "stable" once the window fills
        assert!(
            t.rows[0][5] == "stable" || t.rows[0][5] == "no-baseline",
            "{:?}",
            t.rows
        );
    }
}
