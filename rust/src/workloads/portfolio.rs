//! The JUREAP-like application portfolio (paper §VI-A).
//!
//! JUREAP onboarded 70+ applications at heterogeneous maturity; exaCB's
//! incremental-adoption pathway classifies them as *runnability* →
//! *instrumentability* → *reproducibility*. This generator produces a
//! deterministic 72-application portfolio across 8 scientific domains
//! with plausible model parameters, maturity levels, and per-app failure
//! rates (early-access software fails sometimes — the success column has
//! to earn its keep).

use super::scalable::AppModel;
use crate::util::prng::Prng;

/// A maturity-level string the ladder does not know.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MaturityError(pub String);

impl std::fmt::Display for MaturityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown maturity level '{}' (expected 'runnability', \
             'instrumentability' or 'reproducibility')",
            self.0
        )
    }
}

impl std::error::Error for MaturityError {}

/// The incremental-adoption maturity ladder (paper contribution 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Maturity {
    /// Benchmark runs and reports runtime — nothing more.
    Runnability,
    /// Instrumented: extra metrics (kernel times, bandwidths, energy).
    Instrumentability,
    /// Fully reproducible: pinned sources, validated outputs, seeds.
    Reproducibility,
}

/// Every rung, lowest first (iteration order matches `Ord`).
pub const LEVELS: [Maturity; 3] = [
    Maturity::Runnability,
    Maturity::Instrumentability,
    Maturity::Reproducibility,
];

impl Maturity {
    pub fn name(&self) -> &'static str {
        match self {
            Maturity::Runnability => "runnability",
            Maturity::Instrumentability => "instrumentability",
            Maturity::Reproducibility => "reproducibility",
        }
    }

    /// Parse a level name; anything that is not a ladder rung is a loud
    /// error (mirroring [`crate::coordinator::Launcher::parse`] — a
    /// typo'd `target` on a maturity gate must fail CI validation, not
    /// silently assess against the wrong rung).
    pub fn parse(s: &str) -> Result<Maturity, MaturityError> {
        for level in LEVELS {
            if s.eq_ignore_ascii_case(level.name()) {
                return Ok(level);
            }
        }
        Err(MaturityError(s.to_string()))
    }
}

impl std::fmt::Display for Maturity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

pub const DOMAINS: [&str; 8] = [
    "climate",
    "molecular-dynamics",
    "lattice-qcd",
    "cfd",
    "neuroscience",
    "materials",
    "astrophysics",
    "ai-training",
];

/// One portfolio application.
#[derive(Debug, Clone, PartialEq)]
pub struct PortfolioApp {
    pub name: String,
    pub domain: String,
    pub maturity: Maturity,
    pub model: AppModel,
    /// Per-run failure probability (flaky early-access software).
    pub failure_rate: f64,
    /// Default node count of its standard use case.
    pub nodes: u64,
}

impl PortfolioApp {
    /// Build a portfolio app from a loaded definition (DESIGN.md §15).
    /// Infallible: `defs::validate` has already checked ranges and names,
    /// and the maturity string was typed at parse time.
    pub fn from_def(def: &crate::defs::AppDef) -> PortfolioApp {
        PortfolioApp {
            name: def.name.clone(),
            domain: def.domain.clone(),
            maturity: def.maturity,
            model: AppModel::from_def(def),
            failure_rate: def.failure_rate,
            nodes: def.nodes,
        }
    }

    /// The harness command line of this app's standard benchmark.
    pub fn command(&self) -> String {
        format!(
            "simapp --name {} --flops {:.0} --serial {:.4} --membound {:.3} --comm-mb {:.1} --steps {}",
            self.name,
            self.model.gflops_total,
            self.model.serial_frac,
            self.model.mem_bound,
            self.model.comm_mb,
            self.model.steps
        )
    }
}

/// Deterministically generate an `n`-application portfolio.
pub fn generate(n: usize, seed: u64) -> Vec<PortfolioApp> {
    let mut rng = Prng::new(seed);
    let mut apps = Vec::with_capacity(n);
    for i in 0..n {
        let domain = DOMAINS[i % DOMAINS.len()];
        let mut app_rng = rng.fork(i as u64);
        // maturity mix per §VI-A: "some ... only at the runnability stage,
        // others already provided instrumentation, and a subset had
        // reached full reproducibility"
        let maturity = match app_rng.f64() {
            p if p < 0.40 => Maturity::Runnability,
            p if p < 0.80 => Maturity::Instrumentability,
            _ => Maturity::Reproducibility,
        };
        let mem_bound = app_rng.range_f64(0.15, 0.9);
        let model = AppModel {
            name: format!("{domain}-{:02}", i + 1),
            gflops_total: app_rng.range_f64(5_000.0, 500_000.0),
            serial_frac: app_rng.range_f64(0.002, 0.08),
            mem_bound,
            comm_mb: app_rng.range_f64(4.0, 256.0),
            steps: app_rng.range_u64(20, 400),
            weak: false,
        };
        // mature apps fail less
        let failure_rate = match maturity {
            Maturity::Runnability => app_rng.range_f64(0.05, 0.20),
            Maturity::Instrumentability => app_rng.range_f64(0.02, 0.08),
            Maturity::Reproducibility => app_rng.range_f64(0.0, 0.03),
        };
        apps.push(PortfolioApp {
            name: model.name.clone(),
            domain: domain.to_string(),
            maturity,
            model,
            failure_rate,
            nodes: 1 << app_rng.range_u64(0, 4), // 1..16 nodes
        });
    }
    apps
}

/// The standard JUREAP-scale portfolio (72 applications, fixed seed).
pub fn jureap() -> Vec<PortfolioApp> {
    generate(72, 20260101)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jureap_portfolio_shape() {
        let apps = jureap();
        assert_eq!(apps.len(), 72);
        // all domains represented
        for d in DOMAINS {
            assert!(apps.iter().any(|a| a.domain == d), "{d}");
        }
        // all maturity levels present (§VI-A requirement)
        for m in [
            Maturity::Runnability,
            Maturity::Instrumentability,
            Maturity::Reproducibility,
        ] {
            let count = apps.iter().filter(|a| a.maturity == m).count();
            assert!(count >= 5, "{m:?}: {count}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(10, 42);
        let b = generate(10, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.model, y.model);
            assert_eq!(x.maturity, y.maturity);
        }
        let c = generate(10, 43);
        assert!(a.iter().zip(&c).any(|(x, y)| x.model != y.model));
    }

    #[test]
    fn mature_apps_are_more_reliable() {
        let apps = jureap();
        let avg = |m: Maturity| {
            let v: Vec<f64> = apps
                .iter()
                .filter(|a| a.maturity == m)
                .map(|a| a.failure_rate)
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(avg(Maturity::Runnability) > avg(Maturity::Instrumentability));
        assert!(avg(Maturity::Instrumentability) > avg(Maturity::Reproducibility));
    }

    #[test]
    fn commands_are_runnable() {
        use super::super::testutil::with_ctx;
        let apps = generate(5, 7);
        for app in &apps {
            let cmd = app.command();
            with_ctx("jupiter", app.nodes, |ctx| {
                let out = super::super::run_command(&cmd, ctx);
                assert!(out.success, "{cmd}");
                assert_eq!(out.metrics.str_of("app"), Some(app.name.as_str()));
            });
        }
    }

    #[test]
    fn maturity_ordering() {
        assert!(Maturity::Runnability < Maturity::Instrumentability);
        assert!(Maturity::Instrumentability < Maturity::Reproducibility);
        assert!(LEVELS.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn maturity_parse_roundtrips_and_rejects_typos() {
        for level in LEVELS {
            assert_eq!(Maturity::parse(level.name()), Ok(level));
            assert_eq!(Maturity::parse(&level.name().to_uppercase()), Ok(level));
            assert_eq!(format!("{level}"), level.name());
        }
        let err = Maturity::parse("reproducable").unwrap_err();
        assert!(err.to_string().contains("reproducable"), "{err}");
        assert!(err.to_string().contains("expected"), "{err}");
        assert!(Maturity::parse("").is_err());
    }
}
