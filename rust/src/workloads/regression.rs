//! Seeded injected-regression scenario (DESIGN.md §9): a benchmark
//! repository whose workload is deliberately slowed down by a planted
//! source change on a chosen day, to exercise the regression gate's
//! true-positive behaviour — and, with a 0% shift, its false-positive
//! behaviour.
//!
//! This module is pure model (simulation layer): it produces the JUBE
//! definition, the CI configuration (execution + `regression-check@v1`),
//! and the per-day command lines. `tracking::run_scenario` assembles the
//! repository and drives the campaign.
//!
//! The planted slowdown scales the `simapp` work (`--flops`) by
//! `1 + shift_pct/100` from `inject_day` on. With the default sizing the
//! compute term dominates the runtime model (serial + parallel ≫ the
//! fixed 1 s init/teardown), so the *effective* runtime step is within a
//! couple of percent of the nominal shift
//! ([`RegressionScenario::effective_shift_pct`]).

/// One injected-regression campaign definition.
#[derive(Debug, Clone, PartialEq)]
pub struct RegressionScenario {
    /// Repository / application name.
    pub app: String,
    pub machine: String,
    pub queue: String,
    pub project: String,
    pub budget: String,
    /// Simulated campaign length in days.
    pub days: i64,
    /// Day the slowdown lands (`None` = control scenario, no change).
    pub inject_day: Option<i64>,
    /// Nominal planted slowdown in percent of the compute work.
    pub shift_pct: f64,
    /// Campaign seed (the caller seeds the world with it; recorded here
    /// so reports of true/false-positive runs are reproducible).
    pub seed: u64,
    pub nodes: u64,
    /// Total work at reference size [GFLOP] — sized so runtime ≫ the
    /// model's fixed 1 s overhead.
    pub flops: f64,
    pub steps: u64,
    // gate policy forwarded into the CI config
    pub metric: String,
    pub threshold_pct: u64,
    pub confidence_pct: u64,
    pub min_repetitions: u64,
    pub max_extra_repetitions: u64,
    pub baseline_window: u64,
    pub min_baseline: u64,
}

impl RegressionScenario {
    fn base(machine: &str, days: i64, seed: u64) -> RegressionScenario {
        RegressionScenario {
            app: "rgapp".into(),
            machine: machine.to_string(),
            queue: "all".into(),
            project: "cjsc".into(),
            budget: "zam".into(),
            days,
            inject_day: None,
            shift_pct: 0.0,
            seed,
            nodes: 1,
            flops: 200_000.0,
            steps: 10,
            // pins the catalog defaults (ci::component::
            // regression_check_defaults — not importable from the
            // simulation layer) so campaign assertions cannot drift
            // silently if the defaults move
            metric: "runtime".into(),
            threshold_pct: 5,
            confidence_pct: 95,
            min_repetitions: 4,
            max_extra_repetitions: 6,
            baseline_window: 10,
            min_baseline: 4,
        }
    }

    /// A campaign with a planted `shift_pct` slowdown landing on
    /// `inject_day`.
    pub fn planted(
        machine: &str,
        days: i64,
        inject_day: i64,
        shift_pct: f64,
        seed: u64,
    ) -> RegressionScenario {
        RegressionScenario {
            inject_day: Some(inject_day),
            shift_pct,
            ..Self::base(machine, days, seed)
        }
    }

    /// The 0%-shift control: an unchanged branch that must stay green.
    pub fn control(machine: &str, days: i64, seed: u64) -> RegressionScenario {
        Self::base(machine, days, seed)
    }

    /// The execution prefix (`machine.app`) the gate tracks.
    pub fn prefix(&self) -> String {
        format!("{}.{}", self.machine, self.app)
    }

    /// True when `day` runs the slowed-down source.
    pub fn injected(&self, day: i64) -> bool {
        matches!(self.inject_day, Some(d) if day >= d && self.shift_pct > 0.0)
    }

    /// The workload command line for a given day.
    pub fn command(&self, day: i64) -> String {
        let factor = if self.injected(day) {
            1.0 + self.shift_pct / 100.0
        } else {
            1.0
        };
        format!(
            "simapp --name {} --flops {:.0} --steps {}",
            self.app,
            self.flops * factor,
            self.steps
        )
    }

    /// The JUBE definition as of `day` (the planted change is a changed
    /// `do:` line — what a regressing merge looks like).
    pub fn jube_file(&self, day: i64) -> String {
        format!(
            "name: {name}\n\
             parametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: {nodes}\n\
             steps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - {cmd}\n",
            name = self.app,
            nodes = self.nodes,
            cmd = self.command(day)
        )
    }

    /// CI configuration: the execution component followed by the
    /// regression gate, both over the same prefix.
    pub fn ci_file(&self) -> String {
        let prefix = self.prefix();
        format!(
            r#"include:
  - component: execution@v3
    inputs:
      prefix: "{prefix}"
      machine: "{machine}"
      queue: "{queue}"
      project: "{project}"
      budget: "{budget}"
      jube_file: "benchmark/jube/app.yml"
  - component: regression-check@v1
    inputs:
      prefix: "{prefix}"
      machine: "{machine}"
      queue: "{queue}"
      project: "{project}"
      budget: "{budget}"
      jube_file: "benchmark/jube/app.yml"
      metric: "{metric}"
      threshold_pct: {threshold}
      confidence_pct: {confidence}
      min_repetitions: {min_reps}
      max_extra_repetitions: {max_extra}
      baseline_window: {window}
      min_baseline: {min_baseline}
schedule:
  every: day
  hour: 3
"#,
            prefix = prefix,
            machine = self.machine,
            queue = self.queue,
            project = self.project,
            budget = self.budget,
            metric = self.metric,
            threshold = self.threshold_pct,
            confidence = self.confidence_pct,
            min_reps = self.min_repetitions,
            max_extra = self.max_extra_repetitions,
            window = self.baseline_window,
            min_baseline = self.min_baseline,
        )
    }

    /// The gate reaches its adaptive minimum by adding this many
    /// repetitions to the pipeline's own execution sample.
    pub fn expected_min_extra(&self) -> u64 {
        self.min_repetitions.saturating_sub(1)
    }

    /// Rough effective runtime step: the nominal shift diluted by the
    /// model's fixed ~1 s init/teardown (compute of this sizing runs
    /// tens of seconds, so the dilution is a few percent of the shift).
    pub fn effective_shift_pct(&self, base_runtime_s: f64) -> f64 {
        if base_runtime_s <= 1.0 {
            return 0.0;
        }
        self.shift_pct * (base_runtime_s - 1.0) / base_runtime_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_command_changes_only_from_inject_day() {
        let sc = RegressionScenario::planted("jedi", 10, 6, 15.0, 42);
        assert_eq!(sc.command(0), sc.command(5));
        assert_ne!(sc.command(5), sc.command(6));
        assert_eq!(sc.command(6), sc.command(9));
        assert!(sc.command(6).contains("--flops 230000"), "{}", sc.command(6));
        assert!(sc.injected(6) && !sc.injected(5));
    }

    #[test]
    fn control_never_changes() {
        let sc = RegressionScenario::control("jedi", 10, 42);
        for d in 0..10 {
            assert_eq!(sc.command(d), sc.command(0));
            assert!(!sc.injected(d));
        }
    }

    #[test]
    fn jube_and_ci_have_the_wiring() {
        let sc = RegressionScenario::planted("jedi", 10, 6, 15.0, 42);
        let jube = sc.jube_file(0);
        assert!(jube.contains("remote: true"));
        assert!(jube.contains("simapp --name rgapp"));
        let ci = sc.ci_file();
        assert!(ci.contains("component: execution@v3"));
        assert!(ci.contains("component: regression-check@v1"));
        assert!(ci.contains("threshold_pct: 5"));
        assert!(ci.contains(&format!("prefix: \"{}\"", sc.prefix())));
    }

    #[test]
    fn effective_shift_is_close_to_nominal_for_long_runs() {
        let sc = RegressionScenario::planted("jedi", 10, 6, 15.0, 42);
        let eff = sc.effective_shift_pct(60.0);
        assert!(eff > 14.0 && eff < 15.0, "{eff}");
        assert_eq!(sc.effective_shift_pct(0.5), 0.0);
    }
}
