//! The chaos-fleet scenario (DESIGN.md §14): a multi-day collection
//! campaign under an armed seeded fault model.
//!
//! One [`ChaosScenario`] value describes everything adversarial about a
//! campaign — per-machine node-failure and preemption rates, a scheduler
//! outage, a maintenance drain, a fleet-wide stack-update day with a
//! correlated performance shift, and a forced-flaky window for one app —
//! all derived purely from `(seed, machine, day)`. Arming the scenario
//! on a [`World`] installs per-machine [`FaultPlan`]s on the batch
//! systems and plants the stack-update [`SystemEvent`]s in the cluster
//! event log; the campaign itself is the ordinary concurrent collection
//! runner, so every fault flows through the same O(log n) event heap
//! that fault-free campaigns use and replays byte-identically under
//! `drive` and `drive_reference`.
//!
//! The inert variant ([`ChaosScenario::quiet`]) arms zero rates and no
//! windows: contractually byte-identical to never arming anything
//! (asserted by `tests/integration_chaos.rs`).

use crate::cluster::{EventLog, SystemEvent};
use crate::coordinator::event_loop::PipelineTask;
use crate::coordinator::{collection, CollectionSummary, World};
use crate::scheduler::{FaultKind, FaultPlan, ForcedFault, Window};
use crate::util::fnv1a;
use crate::workloads::portfolio::{self, PortfolioApp};

/// A fully-specified chaos campaign: which apps run where for how long,
/// and every fault the fleet suffers along the way.
#[derive(Debug, Clone)]
pub struct ChaosScenario {
    pub apps: Vec<PortfolioApp>,
    pub machines: Vec<String>,
    pub days: i64,
    pub seed: u64,
    /// Per-start node-failure probability on every machine.
    pub node_fail_rate: f64,
    /// Per-start preemption probability on every machine.
    pub preempt_rate: f64,
    /// Day of the scheduler outage on `machines[0]` (02:00–04:00);
    /// negative = no outage.
    pub outage_day: i64,
    /// Day `machines[0]` drains for maintenance (02:00–08:00);
    /// negative = no maintenance.
    pub maintenance_day: i64,
    /// Fleet-wide stack-update day (negative = none): every metric
    /// class on every machine shifts to `stack_update_factor`, changing
    /// the environment fingerprint — and with it every cache key —
    /// everywhere at once.
    pub stack_update_day: i64,
    pub stack_update_factor: f64,
    /// App made flaky on a forced schedule: every start inside
    /// `[flaky_from_day, flaky_from_day + flaky_days)` node-fails.
    /// Empty app name or non-positive `flaky_days` = no forced window.
    pub flaky_app: String,
    pub flaky_from_day: i64,
    pub flaky_days: i64,
}

impl ChaosScenario {
    /// The standard 30-day chaos campaign: `n` portfolio apps spread
    /// over two machines, moderate fault rates, one outage, one
    /// maintenance drain, one fleet-wide stack-update day, and one app
    /// forced flaky for a week. App-level `failure_rate` is zeroed so
    /// every failure in the campaign is attributable to the fault plan.
    pub fn generate(n: usize, days: i64, seed: u64) -> ChaosScenario {
        let mut apps = portfolio::generate(n, seed);
        for a in &mut apps {
            a.failure_rate = 0.0;
        }
        let flaky_app = apps.first().map(|a| a.name.clone()).unwrap_or_default();
        ChaosScenario {
            apps,
            machines: vec!["jedi".into(), "jupiter".into()],
            days,
            seed,
            node_fail_rate: 0.08,
            preempt_rate: 0.05,
            outage_day: days / 3,
            maintenance_day: 2 * days / 3,
            stack_update_day: days / 2,
            stack_update_factor: 0.85,
            flaky_app,
            flaky_from_day: days / 4,
            flaky_days: 7,
        }
    }

    /// The inert scenario: same apps and schedule, zero rates, no
    /// windows, no events. Arming it must change no byte of anything.
    pub fn quiet(n: usize, days: i64, seed: u64) -> ChaosScenario {
        ChaosScenario {
            node_fail_rate: 0.0,
            preempt_rate: 0.0,
            outage_day: -1,
            maintenance_day: -1,
            stack_update_day: -1,
            flaky_app: String::new(),
            flaky_days: 0,
            ..ChaosScenario::generate(n, days, seed)
        }
    }

    /// The fault plan this scenario arms on `machine` — a pure function
    /// of the scenario, so re-arming a replay reproduces it exactly.
    pub fn fault_plan(&self, machine: &str) -> FaultPlan {
        let mut plan = FaultPlan::seeded(machine, self.seed ^ fnv1a(b"chaos"));
        plan.node_fail_rate = self.node_fail_rate;
        plan.preempt_rate = self.preempt_rate;
        // outage + maintenance strike the first machine only: the rest
        // of the fleet keeps running, which is what makes the campaign's
        // degradation graceful rather than total
        if Some(machine) == self.machines.first().map(String::as_str) {
            if self.outage_day >= 0 {
                plan.outages.push(Window::on_day(self.outage_day, 2, 4));
            }
            if self.maintenance_day >= 0 {
                plan.maintenance
                    .push(Window::on_day(self.maintenance_day, 2, 8));
            }
        }
        if !self.flaky_app.is_empty() && self.flaky_days > 0 {
            plan.forced.push(ForcedFault {
                name_contains: self.flaky_app.clone(),
                window: Window::new(
                    crate::util::timeutil::SimTime::from_days(self.flaky_from_day),
                    crate::util::timeutil::SimTime::from_days(
                        self.flaky_from_day + self.flaky_days,
                    ),
                ),
                kind: FaultKind::NodeFail,
            });
        }
        plan
    }

    /// The stack-update events this scenario plants (possibly none).
    pub fn system_events(&self) -> Vec<SystemEvent> {
        if self.stack_update_day < 0 {
            return Vec::new();
        }
        let machines: Vec<&str> = self.machines.iter().map(String::as_str).collect();
        EventLog::stack_update(&machines, self.stack_update_day, self.stack_update_factor)
    }

    /// Arm the scenario on a world: install each machine's fault plan
    /// and plant the stack-update events. Idempotent per world.
    pub fn arm(&self, world: &mut World) {
        for machine in &self.machines {
            if let Some(bs) = world.batch.get_mut(machine) {
                bs.set_fault_plan(Some(self.fault_plan(machine)));
            }
        }
        for ev in self.system_events() {
            world.cluster.events.push(ev);
        }
    }
}

/// Onboard the scenario's apps, arm its faults, and run the campaign
/// through the concurrent event-loop core.
pub fn run_chaos_campaign(world: &mut World, scenario: &ChaosScenario) -> CollectionSummary {
    run_chaos_campaign_with(world, scenario, crate::coordinator::event_loop::drive)
}

/// [`run_chaos_campaign`] with a pluggable event loop, so the headline
/// chaos harness can replay the same campaign through `drive` and
/// `drive_reference` and require byte-identical worlds.
pub fn run_chaos_campaign_with(
    world: &mut World,
    scenario: &ChaosScenario,
    drive: fn(&mut World, Vec<PipelineTask>) -> Vec<u64>,
) -> CollectionSummary {
    let machines: Vec<&str> = scenario.machines.iter().map(String::as_str).collect();
    collection::onboard_multi(world, &scenario.apps, &machines, "all");
    scenario.arm(world);
    collection::run_campaign_concurrent_with(world, &scenario.apps, &machines, scenario.days, drive)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::JobState;

    #[test]
    fn scenario_is_deterministic() {
        let a = ChaosScenario::generate(6, 30, 77);
        let b = ChaosScenario::generate(6, 30, 77);
        assert_eq!(a.apps.len(), b.apps.len());
        for (m, n) in a.machines.iter().zip(&b.machines) {
            assert_eq!(m, n);
            assert_eq!(format!("{:?}", a.fault_plan(m)), format!("{:?}", b.fault_plan(n)));
        }
        assert_eq!(a.system_events(), b.system_events());
        // every app failure is attributable to the fault plan
        assert!(a.apps.iter().all(|app| app.failure_rate == 0.0));
    }

    #[test]
    fn windows_land_on_the_first_machine_only() {
        let s = ChaosScenario::generate(4, 30, 5);
        let first = s.fault_plan(&s.machines[0]);
        let other = s.fault_plan(&s.machines[1]);
        assert_eq!(first.outages.len(), 1);
        assert_eq!(first.maintenance.len(), 1);
        assert!(other.outages.is_empty());
        assert!(other.maintenance.is_empty());
        // rates and the forced-flaky window are fleet-wide
        assert_eq!(other.node_fail_rate, s.node_fail_rate);
        assert_eq!(other.forced.len(), 1);
    }

    #[test]
    fn quiet_scenario_arms_nothing() {
        let s = ChaosScenario::quiet(4, 30, 5);
        for m in &s.machines {
            let p = s.fault_plan(m);
            assert_eq!(p.node_fail_rate, 0.0);
            assert_eq!(p.preempt_rate, 0.0);
            assert!(p.outages.is_empty() && p.maintenance.is_empty() && p.forced.is_empty());
        }
        assert!(s.system_events().is_empty());
    }

    #[test]
    fn short_armed_campaign_faults_and_degrades_gracefully() {
        let mut s = ChaosScenario::generate(4, 4, 13);
        s.node_fail_rate = 0.2;
        s.preempt_rate = 0.1;
        s.outage_day = -1;
        s.maintenance_day = -1;
        s.stack_update_day = -1;
        // force the flaky app to node-fail on every start, all 4 days:
        // its pipelines fail *deterministically* (retries are struck too)
        s.flaky_from_day = 0;
        s.flaky_days = 4;
        let mut world = World::new(13);
        let summary = run_chaos_campaign(&mut world, &s);
        // every pipeline ran to a recorded verdict — failed runs are
        // recorded as failed, never dropped
        assert_eq!(summary.pipelines_run, 16);
        assert!(summary.pipelines_succeeded >= 1);
        assert!(
            summary.pipelines_succeeded <= summary.pipelines_run - s.days as usize,
            "the forced-flaky app's daily pipelines must all fail"
        );
        let faults: usize = s
            .machines
            .iter()
            .filter_map(|m| world.batch.get(m))
            .flat_map(|b| b.records())
            .filter(|r| {
                matches!(r.state, JobState::NodeFail | JobState::Preempted)
            })
            .count();
        assert!(faults > 0, "armed campaign must actually fault");
    }
}
