//! Seeded JUREAP-style onboarding scenario (DESIGN.md §10): a portfolio
//! whose applications *declare* maturity levels but must **re-earn**
//! them from recorded evidence, day by day, through the
//! `maturity-check@v1` gate.
//!
//! This module is pure model (simulation layer): it produces per-day
//! JUBE definitions, the CI configuration (execution +
//! `maturity-check@v1`), and the planted event schedule —
//! `maturity::campaign::run_onboarding` assembles the repositories and
//! drives the multi-day campaign over the concurrent event core.
//!
//! Planted events, all deterministic so tests can assert the **exact
//! earn day** of every transition:
//!
//! * `instrument_from` — the day the team adds analysis instrumentation
//!   to the benchmark definition (a planted *promotion* to
//!   instrumentability once enough instrumented runs are recorded);
//! * `verify_from` — the day the team opts into the replay audit
//!   (pinned sources + seeded validation), making the app eligible for
//!   the byte-identical cache-replay proof that reproducibility demands;
//! * `break_day` / `fix_day` — a flaky stretch where every run crashes:
//!   windowed evidence decays, the app *demotes*, the team fixes it and
//!   re-earns the level.

use super::portfolio::{self, Maturity, PortfolioApp};

/// One onboarding application: a portfolio app plus its planted
/// improvement/breakage schedule.
#[derive(Debug, Clone)]
pub struct OnboardingApp {
    pub app: PortfolioApp,
    /// Level the team claims at onboarding time (must be re-earned).
    pub declared: Maturity,
    /// First day the definition carries analysis instrumentation
    /// (`None` = never instrumented).
    pub instrument_from: Option<i64>,
    /// First day the team opts into the reproducibility replay audit
    /// (`None` = never).
    pub verify_from: Option<i64>,
    /// First day every run crashes (`None` = always healthy).
    pub break_day: Option<i64>,
    /// Day the crash is fixed (meaningful only with `break_day`).
    pub fix_day: Option<i64>,
}

impl OnboardingApp {
    /// Is the benchmark definition instrumented on `day`?
    pub fn instrumented_on(&self, day: i64) -> bool {
        matches!(self.instrument_from, Some(d) if day >= d)
    }

    /// Does every run crash on `day`?
    pub fn broken_on(&self, day: i64) -> bool {
        match (self.break_day, self.fix_day) {
            (Some(b), Some(f)) => day >= b && day < f,
            (Some(b), None) => day >= b,
            _ => false,
        }
    }

    /// Has the team opted into the replay audit by `day`?
    pub fn verifying_on(&self, day: i64) -> bool {
        matches!(self.verify_from, Some(d) if day >= d)
    }

    /// The workload command line as of `day`.
    pub fn command(&self, day: i64) -> String {
        if self.broken_on(day) {
            // the crash is a source defect: a changed command (= commit)
            "crashing-binary --boom".to_string()
        } else {
            self.app.command()
        }
    }

    /// The JUBE definition as of `day`: instrumentation appears on
    /// `instrument_from` (exactly the incremental-adoption step the
    /// paper describes), breakage swaps the launch line.
    pub fn jube_file(&self, day: i64) -> String {
        let mut jube = format!(
            "name: {name}\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: {nodes}\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - {cmd}\n",
            name = self.app.name,
            nodes = self.app.nodes,
            cmd = self.command(day)
        );
        if self.instrumented_on(day) {
            jube.push_str(
                "analysis:\n  - name: tts_file\n    file: app.out\n    regex: \"time: ([0-9.eE+-]+)\"\n    type: float\n",
            );
        }
        jube
    }
}

/// A complete onboarding campaign definition.
#[derive(Debug, Clone)]
pub struct OnboardingScenario {
    pub apps: Vec<OnboardingApp>,
    /// Simulated campaign length in days.
    pub days: i64,
    /// Machines the portfolio is spread across (round-robin by index).
    pub machines: Vec<String>,
    pub queue: String,
    pub seed: u64,
    /// Every `verify_every`-th day is a replay-audit day (the campaign
    /// runs opted-in apps twice under a fresh execution cache, so the
    /// second run must replay byte-identically).
    pub verify_every: i64,
    // Gate policy pinned into the generated CI configs. These mirror
    // the `maturity-check@v1` catalog defaults
    // (`ci::component::maturity_check_defaults` — not importable from
    // the simulation layer) so campaign assertions cannot drift
    // silently if the defaults move.
    pub min_runs: u64,
    pub min_instrumented: u64,
    pub window_days: u64,
}

impl OnboardingScenario {
    /// Deterministically generate an `n`-application onboarding
    /// campaign. Planted schedules are index-derived, so the expected
    /// transition days are exactly computable:
    ///
    /// * declared ≥ instrumentability → instrumented from day 0;
    /// * every 3rd runnability-declared app instruments on day
    ///   `days / 3` (planted promotion);
    /// * reproducibility-declared apps join the replay audit on day 0;
    ///   every 4th instrumentability-declared app joins on `days / 2`
    ///   (planted promotion to the top rung);
    /// * every 5th instrumentability-declared app breaks on `days / 3`
    ///   and is fixed on `2 * days / 3` (planted demotion + re-earn).
    pub fn generate(n: usize, days: i64, seed: u64) -> OnboardingScenario {
        let portfolio = portfolio::generate(n, seed);
        let mut apps = Vec::with_capacity(n);
        let (mut n_run, mut n_instr) = (0usize, 0usize);
        for pa in portfolio {
            let declared = pa.maturity;
            let mut oa = OnboardingApp {
                app: pa,
                declared,
                instrument_from: None,
                verify_from: None,
                break_day: None,
                fix_day: None,
            };
            // evidence must be earnable: the campaign injects failures
            // only through the planted break/fix windows
            oa.app.failure_rate = 0.0;
            match declared {
                Maturity::Runnability => {
                    if n_run % 3 == 0 {
                        oa.instrument_from = Some(days / 3);
                    }
                    n_run += 1;
                }
                Maturity::Instrumentability => {
                    oa.instrument_from = Some(0);
                    if n_instr % 4 == 0 {
                        oa.verify_from = Some(days / 2);
                    } else if n_instr % 5 == 1 {
                        oa.break_day = Some(days / 3);
                        oa.fix_day = Some(2 * days / 3);
                    }
                    n_instr += 1;
                }
                Maturity::Reproducibility => {
                    oa.instrument_from = Some(0);
                    oa.verify_from = Some(0);
                }
            }
            apps.push(oa);
        }
        OnboardingScenario {
            apps,
            days,
            machines: vec!["jupiter".to_string()],
            queue: "all".to_string(),
            seed,
            verify_every: 4,
            min_runs: 3,
            min_instrumented: 3,
            window_days: 6,
        }
    }

    /// The standard JUREAP-scale onboarding campaign (72 applications,
    /// fixed seed — the same portfolio `portfolio::jureap` generates).
    pub fn jureap(days: i64) -> OnboardingScenario {
        Self::generate(72, days, 20260101)
    }

    /// The machine application `i` is onboarded to (round-robin).
    pub fn machine_for(&self, i: usize) -> &str {
        &self.machines[i % self.machines.len()]
    }

    /// Replay-audit days: every `verify_every`-th day, starting at day
    /// `verify_every - 1` (never day 0 — there is nothing to replay).
    pub fn is_verification_day(&self, day: i64) -> bool {
        self.verify_every > 0 && day % self.verify_every == self.verify_every - 1
    }

    /// First replay-audit day at or after `day` (if any remain).
    pub fn next_verification_day(&self, day: i64) -> Option<i64> {
        (day.max(0)..self.days).find(|d| self.is_verification_day(*d))
    }

    /// The execution prefix (`machine.app`) of application `i`.
    pub fn prefix(&self, i: usize) -> String {
        format!("{}.{}", self.machine_for(i), self.apps[i].app.name)
    }

    /// CI configuration of application `i`: the execution component
    /// followed by the maturity gate in assess mode (empty `target` —
    /// the gate re-levels the repository instead of blocking).
    pub fn ci_file(&self, i: usize) -> String {
        let machine = self.machine_for(i);
        format!(
            r#"include:
  - component: execution@v3
    inputs:
      prefix: "{prefix}"
      machine: "{machine}"
      queue: "{queue}"
      project: "cexalab"
      budget: "exalab"
      jube_file: "benchmark/jube/app.yml"
  - component: maturity-check@v1
    inputs:
      prefix: "{prefix}"
      min_runs: {min_runs}
      min_instrumented: {min_instrumented}
      window_days: {window}
schedule:
  every: day
  hour: 3
"#,
            prefix = self.prefix(i),
            machine = machine,
            queue = self.queue,
            min_runs = self.min_runs,
            min_instrumented = self.min_instrumented,
            window = self.window_days,
        )
    }

    // ---- expected transition days (healthy apps, daily runs) ----------

    /// Day a healthy app has recorded `min_runs` successful runs.
    pub fn expected_runnability_day(&self) -> i64 {
        self.min_runs as i64 - 1
    }

    /// Day app `i` earns instrumentability: `min_instrumented`
    /// instrumented successful runs after `instrument_from`.
    pub fn expected_instrumentability_day(&self, i: usize) -> Option<i64> {
        let from = self.apps[i].instrument_from?;
        Some((from + self.min_instrumented as i64 - 1).max(self.expected_runnability_day()))
    }

    /// Day app `i` earns reproducibility: the first replay-audit day on
    /// which it is both instrumentability-earned and opted in.
    pub fn expected_reproducibility_day(&self, i: usize) -> Option<i64> {
        let verify = self.apps[i].verify_from?;
        let instr = self.expected_instrumentability_day(i)?;
        self.next_verification_day(verify.max(instr))
    }

    /// Day a broken app's windowed successes drop below `min_runs`:
    /// `break_day + window_days - min_runs`. Exact when the app was
    /// healthy for ≥ `min_runs` days before breaking **and** the fix
    /// lands after this day (`fix_day > break_day + window_days -
    /// min_runs` — otherwise the window refills before it ever drains);
    /// the generated break/fix schedules guarantee both for campaigns
    /// of ≥ 11 days.
    pub fn expected_demotion_day(&self, i: usize) -> Option<i64> {
        let b = self.apps[i].break_day?;
        Some(b + self.window_days as i64 - self.min_runs as i64)
    }

    /// Day a fixed app has re-earned its instrumented level:
    /// `fix_day + min_runs - 1`.
    pub fn expected_repromotion_day(&self, i: usize) -> Option<i64> {
        let f = self.apps[i].fix_day?;
        Some(f + self.min_runs as i64 - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jureap_scenario_shape() {
        let sc = OnboardingScenario::jureap(12);
        assert_eq!(sc.apps.len(), 72);
        // all three declared levels present, and every planted event
        // class occurs at least once
        for level in portfolio::LEVELS {
            assert!(sc.apps.iter().any(|a| a.declared == level), "{level}");
        }
        assert!(sc
            .apps
            .iter()
            .any(|a| a.declared == Maturity::Runnability && a.instrument_from.is_some()));
        assert!(sc
            .apps
            .iter()
            .any(|a| a.declared == Maturity::Instrumentability && a.verify_from.is_some()));
        assert!(sc.apps.iter().any(|a| a.break_day.is_some()));
        // generation is deterministic
        let again = OnboardingScenario::jureap(12);
        for (a, b) in sc.apps.iter().zip(&again.apps) {
            assert_eq!(a.app.name, b.app.name);
            assert_eq!(a.instrument_from, b.instrument_from);
            assert_eq!(a.break_day, b.break_day);
        }
    }

    #[test]
    fn instrumentation_appears_on_schedule() {
        // the jureap portfolio mix guarantees runnability-declared apps
        // (asserted by portfolio::tests::jureap_portfolio_shape)
        let sc = OnboardingScenario::jureap(12);
        let planted = sc
            .apps
            .iter()
            .find(|a| a.declared == Maturity::Runnability && a.instrument_from.is_some())
            .unwrap();
        let day = planted.instrument_from.unwrap();
        assert!(!planted.jube_file(day - 1).contains("analysis:"));
        assert!(planted.jube_file(day).contains("analysis:"));
        assert!(planted.jube_file(day).contains("tts_file"));
    }

    #[test]
    fn breakage_swaps_the_launch_line_and_heals() {
        let sc = OnboardingScenario::jureap(12);
        let (i, broken) = sc
            .apps
            .iter()
            .enumerate()
            .find(|(_, a)| a.break_day.is_some())
            .unwrap();
        let (b, f) = (broken.break_day.unwrap(), broken.fix_day.unwrap());
        assert!(b < f && f < sc.days);
        assert!(!broken.broken_on(b - 1));
        assert!(broken.jube_file(b).contains("crashing-binary"));
        assert_eq!(broken.jube_file(f), broken.jube_file(b - 1));
        // demotion strictly after the break, re-earn after the fix
        assert!(sc.expected_demotion_day(i).unwrap() > b);
        assert!(sc.expected_repromotion_day(i).unwrap() >= f);
    }

    #[test]
    fn verification_days_recur_and_never_start_at_zero() {
        let sc = OnboardingScenario::generate(4, 12, 7);
        assert!(!sc.is_verification_day(0));
        let days: Vec<i64> = (0..sc.days).filter(|d| sc.is_verification_day(*d)).collect();
        assert_eq!(days, vec![3, 7, 11]);
        assert_eq!(sc.next_verification_day(4), Some(7));
        assert_eq!(sc.next_verification_day(12), None);
    }

    #[test]
    fn ci_file_wires_execution_and_gate() {
        let sc = OnboardingScenario::generate(4, 12, 7);
        let ci = sc.ci_file(0);
        assert!(ci.contains("component: execution@v3"));
        assert!(ci.contains("component: maturity-check@v1"));
        assert!(ci.contains(&format!("prefix: \"{}\"", sc.prefix(0))));
        assert!(ci.contains("min_runs: 3"));
        assert!(ci.contains("window_days: 6"));
    }
}
