//! Host calibration: anchoring simulated runtimes in *real measured*
//! compute.
//!
//! When the PJRT engine is available, the logmap/stream workloads execute
//! their AOT HLO artifacts for real and the measured host wall-clock
//! anchors the performance model: simulated time on machine M =
//! host time × (host effective rate / M's modelled rate). Without
//! artifacts (unit tests, cold checkouts) an analytic fallback rate is
//! used so every code path still functions.

use std::time::Duration;

/// Measured (or assumed) host execution rates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostCalibration {
    /// Effective host FLOP rate on the logmap kernel [GFLOP/s].
    pub logmap_gflops: f64,
    /// Effective host STREAM traffic rate [GB/s].
    pub stream_gbs: f64,
    /// True when derived from a real PJRT run (vs the analytic default).
    pub measured: bool,
}

impl Default for HostCalibration {
    fn default() -> Self {
        // Conservative single-core CPU-ish defaults for the fallback path.
        HostCalibration {
            logmap_gflops: 2.0,
            stream_gbs: 8.0,
            measured: false,
        }
    }
}

impl HostCalibration {
    /// Derive a calibration from one measured logmap + stream execution.
    pub fn from_measurements(
        logmap_flops: u64,
        logmap_wall: Duration,
        stream_bytes: u64,
        stream_wall: Duration,
    ) -> HostCalibration {
        let gflops = logmap_flops as f64 / logmap_wall.as_secs_f64().max(1e-9) / 1e9;
        let gbs = stream_bytes as f64 / stream_wall.as_secs_f64().max(1e-9) / 1e9;
        HostCalibration {
            logmap_gflops: gflops.max(0.01),
            stream_gbs: gbs.max(0.01),
            measured: true,
        }
    }

    /// Calibrate from a live engine (one warm-up + one timed run each).
    pub fn measure(
        engine: &mut crate::runtime::Engine,
    ) -> Result<HostCalibration, crate::runtime::EngineError> {
        use crate::runtime::EngineError;
        let logmap = engine
            .manifest
            .best_logmap(512, 65536)
            .ok_or_else(|| EngineError::msg("no logmap artifact"))?
            .clone();
        let stream = engine
            .manifest
            .entries
            .iter()
            .find(|e| e.kind == "stream")
            .ok_or_else(|| EngineError::msg("no stream artifact"))?
            .clone();
        let n = logmap.n();
        let x = vec![0.37f32; n];
        let r = vec![3.61f32; n];
        // warm-up triggers compilation; second run is the measurement
        engine.run_logmap(&logmap.name, &x, &r)?;
        let (_, _, wall_l) = engine.run_logmap(&logmap.name, &x, &r)?;
        engine.run_stream(&stream.name, 0.1)?;
        let (_, wall_s) = engine.run_stream(&stream.name, 0.1)?;
        Ok(HostCalibration::from_measurements(
            logmap.flops,
            wall_l,
            stream.bytes,
            wall_s,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_measurements_computes_rates() {
        let c = HostCalibration::from_measurements(
            2_000_000_000,
            Duration::from_secs(1),
            4_000_000_000,
            Duration::from_millis(500),
        );
        assert!((c.logmap_gflops - 2.0).abs() < 1e-9);
        assert!((c.stream_gbs - 8.0).abs() < 1e-9);
        assert!(c.measured);
    }

    #[test]
    fn default_is_analytic() {
        let c = HostCalibration::default();
        assert!(!c.measured);
        assert!(c.logmap_gflops > 0.0 && c.stream_gbs > 0.0);
    }

    #[test]
    fn measure_with_real_engine() {
        let dir = crate::runtime::manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let Ok(mut eng) = crate::runtime::Engine::load_default() else {
            crate::obs_warn!("skipped: engine backend unavailable");
            return;
        };
        let c = HostCalibration::measure(&mut eng).unwrap();
        assert!(c.measured);
        // plausible host rates: somewhere between 0.01 and 1000
        assert!(c.logmap_gflops > 0.01 && c.logmap_gflops < 1000.0, "{c:?}");
        assert!(c.stream_gbs > 0.01 && c.stream_gbs < 1000.0, "{c:?}");
    }
}
