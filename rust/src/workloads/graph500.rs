//! Graph500-like benchmark: a *real* BFS over a synthetic Kronecker-style
//! graph, with machine-model scaling (Fig. 4's daily workload).
//!
//! Two reported kernels, as in the paper's Fig. 4: BFS (kernel 2) and
//! SSSP (kernel 3), both in TEPS. The graph is generated and traversed
//! for real in Rust (edge counts, reachability, and parent-tree
//! validation are genuine); the reported TEPS maps the measured traversal
//! onto the target machine's model, where BFS at scale is dominated by
//! the interconnect — which is exactly why the fabric-firmware event in
//! Fig. 4 dents this benchmark but not BabelStream.

use super::{AppOutput, AppProfile, CmdLine, ExecCtx};
use crate::cluster::MetricClass;
use crate::util::json::Json;
use crate::util::prng::Prng;

pub const PROFILE: AppProfile = AppProfile {
    utilization: 0.65,
    mem_bound: 0.85,
};

/// Per-GPU baseline BFS rate [GTEPS] for an A100-class device at the
/// reference software stage (tuned so system-scale numbers land in the
/// Graph500-list ballpark).
const BASE_GTEPS_PER_GPU: f64 = 0.9;

/// A CSR graph.
pub struct Graph {
    pub nv: usize,
    pub offsets: Vec<u32>,
    pub targets: Vec<u32>,
}

impl Graph {
    /// Kronecker-flavoured generator: RMAT-style quadrant descent with
    /// the Graph500 (A,B,C) = (0.57, 0.19, 0.19) parameters.
    pub fn kronecker(scale: u32, edgefactor: usize, rng: &mut Prng) -> Graph {
        let nv = 1usize << scale;
        let ne = nv * edgefactor;
        let (a, b, c) = (0.57, 0.19, 0.19);
        let mut edges: Vec<(u32, u32)> = Vec::with_capacity(ne);
        for _ in 0..ne {
            let (mut u, mut v) = (0usize, 0usize);
            for bit in (0..scale).rev() {
                let p = rng.f64();
                let (du, dv) = if p < a {
                    (0, 0)
                } else if p < a + b {
                    (0, 1)
                } else if p < a + b + c {
                    (1, 0)
                } else {
                    (1, 1)
                };
                u |= du << bit;
                v |= dv << bit;
            }
            edges.push((u as u32, v as u32));
            edges.push((v as u32, u as u32)); // undirected
        }
        // degree counting + CSR
        let mut deg = vec![0u32; nv];
        for &(u, _) in &edges {
            deg[u as usize] += 1;
        }
        let mut offsets = vec![0u32; nv + 1];
        for i in 0..nv {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut cursor = offsets[..nv].to_vec();
        let mut targets = vec![0u32; edges.len()];
        for &(u, v) in &edges {
            targets[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
        }
        Graph {
            nv,
            offsets,
            targets,
        }
    }

    pub fn nedges(&self) -> usize {
        self.targets.len() / 2
    }

    /// BFS from `root`: returns (parent array, edges traversed).
    pub fn bfs(&self, root: u32) -> (Vec<i64>, u64) {
        let mut parent = vec![-1i64; self.nv];
        parent[root as usize] = root as i64;
        let mut frontier = vec![root];
        let mut traversed = 0u64;
        while !frontier.is_empty() {
            let mut next = Vec::new();
            for &u in &frontier {
                let (s, e) = (
                    self.offsets[u as usize] as usize,
                    self.offsets[u as usize + 1] as usize,
                );
                for &v in &self.targets[s..e] {
                    traversed += 1;
                    if parent[v as usize] < 0 {
                        parent[v as usize] = u as i64;
                        next.push(v);
                    }
                }
            }
            frontier = next;
        }
        (parent, traversed)
    }

    /// Graph500-style validation: every discovered vertex has a parent
    /// whose BFS level is exactly one smaller.
    pub fn validate_bfs(&self, root: u32, parent: &[i64]) -> bool {
        if parent[root as usize] != root as i64 {
            return false;
        }
        // level by walking parents (with cycle guard)
        let mut level = vec![-1i64; self.nv];
        level[root as usize] = 0;
        for v in 0..self.nv {
            if parent[v] < 0 || level[v] >= 0 {
                continue;
            }
            let mut chain = vec![v];
            let mut cur = parent[v] as usize;
            while level[cur] < 0 {
                if parent[cur] < 0 || chain.len() > self.nv {
                    return false;
                }
                chain.push(cur);
                cur = parent[cur] as usize;
            }
            let mut l = level[cur];
            for &c in chain.iter().rev() {
                l += 1;
                level[c] = l;
            }
        }
        // parent edges must exist in the graph
        for v in 0..self.nv {
            let p = parent[v];
            if p >= 0 && p as usize != v {
                let (s, e) = (self.offsets[v] as usize, self.offsets[v + 1] as usize);
                if !self.targets[s..e].contains(&(p as u32)) {
                    return false;
                }
            }
        }
        true
    }
}

pub fn run(cmd: &CmdLine, ctx: &mut ExecCtx) -> AppOutput {
    let scale = cmd.flag_u64("scale", 16).min(20) as u32;
    let nbfs = cmd.flag_u64("nbfs", 8) as usize;

    // ---- real graph construction + BFS --------------------------------
    let t0 = std::time::Instant::now();
    let mut gen_rng = ctx.rng.fork(scale as u64);
    let graph = Graph::kronecker(scale, 16, &mut gen_rng);
    let mut traversed_total = 0u64;
    let mut success = true;
    for i in 0..nbfs {
        let root = (gen_rng.next_u64() % graph.nv as u64) as u32;
        let (parent, traversed) = graph.bfs(root);
        traversed_total += traversed;
        if i == 0 {
            success &= graph.validate_bfs(root, &parent);
        }
    }
    let host_wall = t0.elapsed().as_secs_f64();
    let host_teps = traversed_total as f64 / host_wall.max(1e-9);

    // ---- machine-model TEPS -------------------------------------------
    let m = ctx.env.machine;
    let net = ctx.env.factor(MetricClass::Network);
    let comp = ctx.env.factor(MetricClass::Compute);
    let gpus = ctx.total_gpus() as f64;
    // BFS at scale: ~70% network-bound, sublinear scaling (0.75 exponent)
    let machine_bfs_gteps = BASE_GTEPS_PER_GPU
        * (m.gpu_gen.hbm_bw_gbs() / 1555.0) // memory-rate generational lift
        * gpus.powf(0.75)
        * net.powf(0.7)
        * comp.powf(0.3)
        * ctx.freq_perf(PROFILE)
        * ctx.env.noise(ctx.rng);
    let machine_sssp_gteps = machine_bfs_gteps * 0.32 * ctx.env.noise(ctx.rng);

    // per-search time on the modelled machine
    let edges_per_search = traversed_total as f64 / nbfs.max(1) as f64;
    let runtime_s =
        5.0 + nbfs as f64 * edges_per_search / (machine_bfs_gteps * 1e9)
            + nbfs as f64 * edges_per_search / (machine_sssp_gteps * 1e9);

    let metrics = Json::obj()
        .set("scale", scale as u64)
        .set("nedges", graph.nedges() as u64)
        .set("bfs_gteps", machine_bfs_gteps)
        .set("sssp_gteps", machine_sssp_gteps)
        .set("BFS harmonic_mean_TEPS", machine_bfs_gteps * 1e9)
        .set("SSSP harmonic_mean_TEPS", machine_sssp_gteps * 1e9)
        .set("host_teps", host_teps)
        .set("host_wall_s", host_wall)
        .set("validation", if success { "pjrt-host" } else { "failed" });

    let out = format!(
        "graph500 (sim)\nSCALE: {scale}\nedgefactor: 16\nNBFS: {nbfs}\n\
         bfs  harmonic_mean_TEPS: {:.4e}\nsssp harmonic_mean_TEPS: {:.4e}\n\
         validation: {}\n",
        machine_bfs_gteps * 1e9,
        machine_sssp_gteps * 1e9,
        if success { "PASSED" } else { "FAILED" }
    );

    AppOutput {
        runtime_s,
        success,
        metrics,
        files: vec![("graph500.out".into(), out)],
        profile: PROFILE,
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::with_ctx;
    use super::super::run_command;
    use super::*;

    #[test]
    fn kronecker_graph_shape() {
        let mut rng = Prng::new(3);
        let g = Graph::kronecker(10, 16, &mut rng);
        assert_eq!(g.nv, 1024);
        assert_eq!(g.nedges(), 1024 * 16);
        assert_eq!(*g.offsets.last().unwrap() as usize, g.targets.len());
    }

    #[test]
    fn bfs_finds_connected_component_and_validates() {
        let mut rng = Prng::new(4);
        let g = Graph::kronecker(10, 16, &mut rng);
        let (parent, traversed) = g.bfs(0);
        assert!(traversed > 0);
        let reached = parent.iter().filter(|&&p| p >= 0).count();
        // Kronecker graphs have a giant component
        assert!(reached > g.nv / 2, "reached={reached}");
        assert!(g.validate_bfs(0, &parent));
    }

    #[test]
    fn validation_rejects_corrupt_tree() {
        let mut rng = Prng::new(5);
        let g = Graph::kronecker(8, 8, &mut rng);
        let (mut parent, _) = g.bfs(0);
        // corrupt: point a reached vertex at itself (fake root)
        if let Some(v) = (1..g.nv).find(|&v| parent[v] >= 0) {
            parent[v] = v as i64;
            assert!(!g.validate_bfs(0, &parent));
        }
    }

    #[test]
    fn app_reports_two_kernels() {
        with_ctx("jupiter", 4, |ctx| {
            let out = run_command("graph500 --scale 12", ctx);
            assert!(out.success);
            let bfs = out.metrics.f64_of("bfs_gteps").unwrap();
            let sssp = out.metrics.f64_of("sssp_gteps").unwrap();
            assert!(bfs > 0.0 && sssp > 0.0 && sssp < bfs);
        });
    }

    #[test]
    fn network_event_dents_teps() {
        use crate::cluster::{Cluster, EventLog, SoftwareStage};
        use crate::util::timeutil::SimTime;
        let cluster =
            Cluster::standard().with_events(EventLog::fig4_scenario("jupiter"));
        let stage = SoftwareStage::stage_2026();
        let run_at = |cluster: &Cluster, day: i64| {
            let env = cluster
                .env_at("jupiter", &stage, SimTime::from_days(day))
                .unwrap();
            let mut rng = Prng::new(9);
            let mut ctx = super::super::ExecCtx {
                env: &env,
                nodes: 4,
                tasks_per_node: 4,
                threads_per_task: 8,
                env_vars: Default::default(),
                freq_mhz: None,
                calibration: Default::default(),
                rng: &mut rng,
                engine: None,
            };
            run_command("graph500 --scale 12", &mut ctx)
                .metrics
                .f64_of("bfs_gteps")
                .unwrap()
        };
        let before = run_at(&cluster, 10);
        let during = run_at(&cluster, 45);
        let after = run_at(&cluster, 70);
        assert!(during < 0.9 * before, "regression visible: {during} vs {before}");
        assert!((after / before - 1.0).abs() < 0.05, "recovery: {after} vs {before}");
    }
}
