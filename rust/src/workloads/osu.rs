//! OSU-microbenchmark-like MPI pt2pt benchmark (Fig. 6's workload).
//!
//! `osu_bw` sweeps message sizes and reports bandwidth per size from the
//! machine's UCX network model; `UCX_RNDV_THRESH` injected through the
//! environment (feature injection, §V-A.3) moves the eager/rendezvous
//! protocol switch and therefore the bandwidth curve — reproducing the
//! exact experiment of Fig. 6.

use super::{parse_rndv_thresh, AppOutput, AppProfile, CmdLine, ExecCtx};
use crate::util::json::Json;

pub const PROFILE: AppProfile = AppProfile {
    utilization: 0.25,
    mem_bound: 0.15,
};

/// Message sizes swept by osu_bw: powers of two, 1 B .. 4 MiB.
pub fn message_sizes() -> Vec<u64> {
    (0..=22).map(|p| 1u64 << p).collect()
}

pub fn run(cmd: &CmdLine, ctx: &mut ExecCtx) -> AppOutput {
    let is_latency = cmd.binary.contains("latency");
    let link = &ctx.env.machine.network;
    let thresh = parse_rndv_thresh(&ctx.env_vars, link.default_rndv_thresh);
    let net_factor = ctx
        .env
        .factor(crate::cluster::MetricClass::Network);

    let mut metrics = Json::obj()
        .set("rndv_thresh", thresh)
        .set("network", link.name.as_str());
    let mut table = Json::arr();
    let mut out_lines = vec![if is_latency {
        "# OSU MPI Latency Test (sim)\n# Size      Latency (us)".to_string()
    } else {
        "# OSU MPI Bandwidth Test (sim)\n# Size      Bandwidth (MB/s)".to_string()
    }];

    let mut total_time_s = 2.0; // startup/teardown
    for size in message_sizes() {
        let noise = ctx.rng.jitter(0.004);
        if is_latency {
            let lat = link.pt2pt_time_us(size, thresh) / net_factor * noise;
            table.push(Json::Arr(vec![Json::Num(size as f64), Json::Num(lat)]));
            out_lines.push(format!("{size:<12}{lat:.2}"));
        } else {
            let bw = ctx.env.pt2pt_bw_mbs(size, thresh) * noise;
            table.push(Json::Arr(vec![Json::Num(size as f64), Json::Num(bw)]));
            out_lines.push(format!("{size:<12}{bw:.2}"));
            // each size runs a window of 64 messages x ~100 iterations
            total_time_s += 6400.0 * link.pt2pt_time_us(size, thresh) / 1e6 / net_factor;
        }
    }
    metrics.insert(if is_latency { "latency_us" } else { "bw_mbs" }, table);
    // headline single-number metric: large-message bandwidth / small latency
    if is_latency {
        metrics.insert(
            "latency_4b_us",
            link.pt2pt_time_us(4, thresh) / net_factor,
        );
    } else {
        metrics.insert("bw_peak_mbs", ctx.env.pt2pt_bw_mbs(4 << 20, thresh));
    }

    AppOutput {
        runtime_s: total_time_s,
        success: true,
        metrics,
        files: vec![(
            if is_latency {
                "osu_latency.out".into()
            } else {
                "osu_bw.out".into()
            },
            out_lines.join("\n") + "\n",
        )],
        profile: PROFILE,
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::with_ctx;
    use super::super::run_command;
    use super::{message_sizes, run};

    fn bw_curve(ctx_thresh: Option<&str>) -> Vec<(f64, f64)> {
        with_ctx("jupiter", 2, |ctx| {
            if let Some(t) = ctx_thresh {
                ctx.env_vars
                    .insert("UCX_RNDV_THRESH".into(), t.to_string());
            }
            let out = run_command("osu_bw", ctx);
            assert!(out.success);
            out.metrics
                .get("bw_mbs")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|row| {
                    let r = row.as_arr().unwrap();
                    (r[0].as_f64().unwrap(), r[1].as_f64().unwrap())
                })
                .collect()
        })
    }

    #[test]
    fn sweeps_all_message_sizes() {
        let curve = bw_curve(None);
        assert_eq!(curve.len(), 23);
        assert_eq!(curve[0].0, 1.0);
        assert_eq!(curve.last().unwrap().0, (4 << 20) as f64);
        // monotone-ish increase to near line rate
        assert!(curve.last().unwrap().1 > 40_000.0);
    }

    #[test]
    fn threshold_changes_the_curve_fig6() {
        let low = bw_curve(Some("1024"));
        let high = bw_curve(Some("intra:65536,inter:1048576"));
        // at 64 KiB: low threshold -> rendezvous, high -> eager
        let at = |curve: &[(f64, f64)], size: f64| {
            curve.iter().find(|(s, _)| *s == size).unwrap().1
        };
        let l = at(&low, 65536.0);
        let h = at(&high, 65536.0);
        assert!(
            (l - h).abs() / l.min(h) > 0.04,
            "curves must differ at mid sizes: {l} vs {h}"
        );
        // at 4 MiB both should be rendezvous... except the 1 MiB threshold
        // still switches at 4 MiB, so both end near line rate
        let l4 = at(&low, (4 << 20) as f64);
        let h4 = at(&high, (4 << 20) as f64);
        assert!((l4 - h4).abs() / l4 < 0.05);
    }

    #[test]
    fn latency_mode_reports_microseconds() {
        with_ctx("jureca", 2, |ctx| {
            let out = run_command("osu_latency", ctx);
            assert!(out.success);
            let lat = out.metrics.f64_of("latency_4b_us").unwrap();
            assert!(lat > 0.5 && lat < 10.0, "{lat}");
        });
    }

    #[test]
    fn files_contain_table() {
        with_ctx("jupiter", 2, |ctx| {
            let out = run_command("osu_bw", ctx);
            let content = &out.files[0].1;
            assert!(content.contains("# Size"));
            assert!(content.lines().count() > 20);
        });
    }
}
