//! BabelStream-like memory-bandwidth benchmark (Fig. 3's daily workload).
//!
//! Reports the five kernel bandwidths (Copy/Mul/Add/Triad/Dot) attained
//! on the target machine: per-GPU attainable bandwidth from the machine
//! model × per-kernel efficiency × run-to-run noise. When the PJRT engine
//! is present, the AOT Pallas stream artifact actually executes and its
//! checksums are validated against the closed form — the `success`
//! column is earned, not assumed.

use super::{AppOutput, AppProfile, CmdLine, ExecCtx};
use crate::util::json::Json;

/// STREAM is the canonical memory-bound workload.
pub const PROFILE: AppProfile = AppProfile {
    utilization: 0.78,
    mem_bound: 0.92,
};

/// (kernel, arrays-moved, efficiency vs attainable copy BW)
const KERNELS: [(&str, u64, f64); 5] = [
    ("copy", 2, 0.985),
    ("mul", 2, 0.980),
    ("add", 3, 1.000),
    ("triad", 3, 1.005),
    ("dot", 2, 0.930),
];

/// Closed-form checksums for a constant-initialised run (mirrors
/// python/compile/model.py::stream_checksums_expected).
pub fn expected_checksums(n: usize, a0: f64, scalar: f64) -> [f64; 5] {
    let c1 = a0;
    let b1 = scalar * c1;
    let c2 = a0 + b1;
    let a1 = b1 + scalar * c2;
    [
        n as f64 * c1,
        n as f64 * b1,
        n as f64 * c2,
        n as f64 * a1,
        a1 * b1 * n as f64,
    ]
}

pub fn run(cmd: &CmdLine, ctx: &mut ExecCtx) -> AppOutput {
    // BabelStream defaults: 2^25 f32 elements per array, 100 repetitions.
    let elems = cmd.flag_u64("size", 1 << 25);
    let reps = cmd.flag_u64("ntimes", 100);
    if elems == 0 || reps == 0 {
        return AppOutput::failure("stream: size and ntimes must be positive");
    }

    let attainable_mbs = ctx.env.stream_bw_mbs() * ctx.freq_perf(PROFILE);
    let mut metrics = Json::obj().set("size", elems).set("ntimes", reps);
    let mut out_lines = vec![format!(
        "BabelStream (sim)\nArray size: {elems} (f32)\nRunning kernels {reps} times"
    )];
    let mut total_time = 0.0;
    for (name, arrays, eff) in KERNELS {
        let bytes = arrays * elems * 4;
        let bw = attainable_mbs * eff * ctx.env.noise(ctx.rng);
        let t = bytes as f64 / (bw * 1e6) * reps as f64;
        total_time += t;
        let label = format!(
            "{} BW [MBytes/sec]",
            capitalize(name)
        );
        metrics.insert(&format!("bw_{name}"), bw);
        metrics.insert(&label, bw);
        out_lines.push(format!("{:<8} {:>14.3} MBytes/sec", capitalize(name), bw));
    }

    // ---- real kernel execution + checksum validation -------------------
    let mut success = true;
    let mut validated = "model";
    if let Some(engine) = ctx.engine.as_deref_mut() {
        let stream_entry = engine
            .manifest
            .entries
            .iter()
            .find(|e| e.kind == "stream")
            .cloned();
        if let Some(entry) = stream_entry {
            match engine.run_stream(&entry.name, 0.1) {
                Ok((sums, wall)) => {
                    let expect = expected_checksums(entry.n(), 0.1, 0.4);
                    success = sums
                        .iter()
                        .zip(expect)
                        .all(|(&got, want)| ((got as f64) - want).abs() < 1e-3 * want.abs());
                    validated = "pjrt";
                    metrics.insert("host_wall_ms", wall.as_secs_f64() * 1e3);
                    metrics.insert(
                        "host_stream_gbs",
                        entry.bytes as f64 / wall.as_secs_f64().max(1e-9) / 1e9,
                    );
                }
                Err(e) => {
                    success = false;
                    metrics.insert("error", format!("pjrt: {e}"));
                }
            }
        }
    }
    metrics.insert("validation", validated);
    out_lines.push(format!(
        "Validation: {}",
        if success { "PASSED" } else { "FAILED" }
    ));

    AppOutput {
        runtime_s: total_time + 1.2, // + allocation & validation overhead
        success,
        metrics,
        files: vec![("babelstream.out".into(), out_lines.join("\n") + "\n")],
        profile: PROFILE,
    }
}

fn capitalize(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::with_ctx;
    use super::super::run_command;
    use super::*;

    #[test]
    fn reports_five_kernel_bandwidths() {
        with_ctx("jupiter", 1, |ctx| {
            let out = run_command("babelstream", ctx);
            assert!(out.success);
            for k in ["copy", "mul", "add", "triad", "dot"] {
                let bw = out.metrics.f64_of(&format!("bw_{k}")).unwrap();
                assert!(bw > 1e5, "{k}: {bw}"); // > 100 GB/s on GH200-class
            }
            // paper-style data labels also present (time-series component input)
            assert!(out.metrics.f64_of("Copy BW [MBytes/sec]").is_some());
        });
    }

    #[test]
    fn bandwidth_reflects_machine_generation() {
        let gh = with_ctx("jupiter", 1, |ctx| {
            run_command("babelstream", ctx)
                .metrics
                .f64_of("bw_triad")
                .unwrap()
        });
        let a100 = with_ctx("jureca", 1, |ctx| {
            run_command("babelstream", ctx)
                .metrics
                .f64_of("bw_triad")
                .unwrap()
        });
        assert!(gh > 2.0 * a100, "GH200 {gh} vs A100 {a100}");
    }

    #[test]
    fn checksums_match_python_oracle_values() {
        // Cross-language consistency: same closed form as model.py
        let e = expected_checksums(256, 0.1, 0.4);
        // from python: c1=0.1, b1=0.04, c2=0.14, a1=0.096, dot=a1*b1*n
        assert!((e[0] - 25.6).abs() < 1e-9);
        assert!((e[1] - 10.24).abs() < 1e-9);
        assert!((e[2] - 35.84).abs() < 1e-9);
        assert!((e[3] - 24.576).abs() < 1e-9);
        assert!((e[4] - 0.096 * 0.04 * 256.0).abs() < 1e-9);
    }

    #[test]
    fn noise_is_small_but_present() {
        // Fig. 3's premise: daily BabelStream stays flat within ~1%
        let mut values = Vec::new();
        for seed in 0..20u64 {
            let v = with_ctx("jupiter", 1, |ctx| {
                *ctx.rng = crate::util::prng::Prng::new(seed);
                run_command("babelstream", ctx)
                    .metrics
                    .f64_of("bw_triad")
                    .unwrap()
            });
            values.push(v);
        }
        let s = crate::util::stats::summary(&values);
        assert!(s.sd / s.mean < 0.02, "cv={}", s.sd / s.mean);
        assert!(s.sd > 0.0);
    }

    #[test]
    fn pjrt_checksum_validation() {
        let dir = crate::runtime::manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let Ok(mut engine) = crate::runtime::Engine::load_default() else {
            crate::obs_warn!("skipped: engine backend unavailable");
            return;
        };
        super::super::testutil::with_ctx_engine("jupiter", 1, Some(&mut engine), |ctx| {
            let out = run_command("babelstream", ctx);
            assert!(out.success);
            assert_eq!(out.metrics.str_of("validation"), Some("pjrt"));
        });
    }
}
