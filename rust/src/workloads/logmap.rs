//! The logmap application (paper §II-A): the running example benchmark.
//!
//! `logmap --workload W --intensity I` iterates the logistic map over a
//! vector of `W × 2²²` values with `I × 1000` iterations per element.
//!
//! Real compute: when the PJRT engine is available the app executes the
//! AOT Pallas kernel (the variant closest to the requested intensity) and
//! validates the output against a scalar Rust reference — that validation
//! is the Table-I `success` column. The *simulated* time-to-solution maps
//! the workload's FLOPs onto the target machine's modelled GPU throughput
//! (generation, software stage, frequency, node count), so runs on JEDI
//! vs JURECA differ exactly as Fig. 5 expects while the numerics stay
//! real.
//!
//! Output files follow §II-A: `logmap.out` (results + `time:` line, the
//! harness analysis target) and `logmap.stats` (kernel metrics).

use super::{AppOutput, AppProfile, CmdLine, ExecCtx};
use crate::cluster::MetricClass;
use crate::util::json::Json;

/// Elements per workload unit.
pub const ELEMS_PER_WORKLOAD: u64 = 1 << 28;
/// Iterations per intensity unit.
pub const ITERS_PER_INTENSITY: f64 = 5000.0;
/// Fraction of GPU FP32 peak a tuned logmap kernel attains (VPU-bound,
/// fused multiply-add chain; the machine models are DESIGN.md §2
/// substrates).
pub const GPU_EFFICIENCY: f64 = 0.22;

/// logmap is compute-dominated: high utilisation, mildly memory-bound.
pub const PROFILE: AppProfile = AppProfile {
    utilization: 0.95,
    mem_bound: 0.25,
};

/// Scalar reference for validation (mirrors kernels/ref.py in f32).
pub fn logmap_scalar(x: f32, r: f32, iters: u64) -> f32 {
    let mut v = x;
    for _ in 0..iters {
        v = r * v * (1.0 - v);
    }
    v
}

pub fn run(cmd: &CmdLine, ctx: &mut ExecCtx) -> AppOutput {
    let workload = cmd.flag_f64("workload", 1.0);
    let intensity = cmd.flag_f64("intensity", 1.0);
    if workload <= 0.0 || intensity <= 0.0 {
        return AppOutput::failure("logmap: workload and intensity must be positive");
    }
    let elems = (workload * ELEMS_PER_WORKLOAD as f64) as u64;
    let iters = (intensity * ITERS_PER_INTENSITY) as u64;
    // kernel-variant intensity for the PJRT validation run (AOT grid is
    // {128, 512, 2048}; see python/compile/aot.py)
    let kernel_iters = (intensity * 1000.0) as u64;
    let flops = 3 * elems * iters;

    // ---- simulated time-to-solution on the target machine -------------
    let m = ctx.env.machine;
    let rate_gflops = m.gpu_gen.peak_tflops() * 1000.0 // GFLOP/s per GPU
        * GPU_EFFICIENCY
        * ctx.env.factor(MetricClass::Compute)
        * ctx.freq_perf(PROFILE)
        * ctx.total_gpus() as f64;
    // embarrassingly parallel map + one final 32-byte/elem-block allreduce
    let compute_s = flops as f64 / (rate_gflops * 1e9);
    let comm_s = m
        .network
        .allreduce_time_us(4 * 1024, ctx.total_gpus())
        / 1e6;
    let setup_s = 0.2; // input generation + output write
    let noise = ctx.env.noise(ctx.rng);
    let runtime_s = (compute_s + comm_s + setup_s) * noise;

    // ---- real kernel execution + validation ---------------------------
    let mut metrics = Json::obj()
        .set("workload", workload)
        .set("intensity", intensity)
        .set("elements", elems)
        .set("kernel_iters", iters)
        .set("gflops", flops as f64 / runtime_s / 1e9);
    let mut success = true;
    let mut validated = "model";
    if let Some(engine) = ctx.engine.as_deref_mut() {
        if let Some(entry) = engine.manifest.best_logmap(kernel_iters, 65536).cloned() {
            let n = entry.n();
            let x: Vec<f32> = (0..n)
                .map(|i| 0.05 + 0.9 * (i as f32 / n as f32))
                .collect();
            let r_val = 3.0 + (intensity as f32).fract().max(0.5);
            let r = vec![r_val; n];
            match engine.run_logmap(&entry.name, &x, &r) {
                Ok((out, summary, wall)) => {
                    // validate a sample of outputs against the scalar ref
                    let mut ok = true;
                    for &i in &[0usize, n / 3, n / 2, n - 1] {
                        let want = logmap_scalar(x[i], r_val, entry.iters());
                        if (out[i] - want).abs() > 1e-3 * want.abs().max(1e-3) {
                            ok = false;
                        }
                    }
                    success = ok;
                    validated = "pjrt";
                    metrics.insert("host_wall_ms", wall.as_secs_f64() * 1e3);
                    metrics.insert("kernel_mean", summary[0] as f64);
                    metrics.insert(
                        "host_gflops",
                        entry.flops as f64 / wall.as_secs_f64().max(1e-9) / 1e9,
                    );
                }
                Err(e) => {
                    success = false;
                    metrics.insert("error", format!("pjrt: {e}"));
                }
            }
        }
    }
    metrics.insert("validation", validated);

    let logmap_out = format!(
        "logmap v1.0\nworkload: {workload}\nintensity: {intensity}\nelements: {elems}\n\
         validation: {}\ntime: {runtime_s:.6}\n",
        if success { "PASSED" } else { "FAILED" }
    );
    let logmap_stats = format!(
        "kernel_time: {:.6}\ncomm_time: {:.6}\nsetup_time: {:.6}\ngflops: {:.3}\n",
        compute_s * noise,
        comm_s * noise,
        setup_s * noise,
        flops as f64 / runtime_s / 1e9,
    );

    AppOutput {
        runtime_s,
        success,
        metrics,
        files: vec![
            ("logmap.out".into(), logmap_out),
            ("logmap.stats".into(), logmap_stats),
        ],
        profile: PROFILE,
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::with_ctx;
    use super::super::{run_command, CmdLine};
    use super::*;

    #[test]
    fn produces_paper_output_files() {
        with_ctx("jedi", 1, |ctx| {
            let out = run_command("logmap --workload 6 --intensity 2.4", ctx);
            assert!(out.success);
            assert!(out.runtime_s > 0.0);
            let names: Vec<&str> = out.files.iter().map(|(n, _)| n.as_str()).collect();
            assert_eq!(names, vec!["logmap.out", "logmap.stats"]);
            let content = &out.files[0].1;
            assert!(content.contains("time: "));
            assert!(content.contains("validation: PASSED"));
        });
    }

    #[test]
    fn runtime_scales_with_workload_and_intensity() {
        with_ctx("jedi", 1, |ctx| {
            let small = run_command("logmap --workload 1 --intensity 1", ctx).runtime_s;
            let big_w = run_command("logmap --workload 8 --intensity 1", ctx).runtime_s;
            let big_i = run_command("logmap --workload 1 --intensity 8", ctx).runtime_s;
            assert!(big_w > 2.0 * small, "w: {big_w} vs {small}");
            assert!(big_i > 2.0 * small, "i: {big_i} vs {small}");
        });
    }

    #[test]
    fn strong_scaling_speedup() {
        let t1 = with_ctx("jedi", 1, |ctx| {
            run_command("logmap --workload 32 --intensity 4", ctx).runtime_s
        });
        let t8 = with_ctx("jedi", 8, |ctx| {
            run_command("logmap --workload 32 --intensity 4", ctx).runtime_s
        });
        let speedup = t1 / t8;
        assert!(speedup > 4.0 && speedup < 8.5, "speedup={speedup}");
    }

    #[test]
    fn generational_gap_matches_fig5_premise() {
        let t_jedi = with_ctx("jedi", 4, |ctx| {
            run_command("logmap --workload 32 --intensity 4", ctx).runtime_s
        });
        let t_jwb = with_ctx("juwels-booster", 4, |ctx| {
            run_command("logmap --workload 32 --intensity 4", ctx).runtime_s
        });
        assert!(
            t_jwb / t_jedi > 2.0,
            "Hopper-class should beat Ampere >2x: {t_jwb} vs {t_jedi}"
        );
    }

    #[test]
    fn frequency_throttling_slows_compute() {
        let nominal = with_ctx("jedi", 1, |ctx| {
            run_command("logmap --workload 8 --intensity 4", ctx).runtime_s
        });
        let throttled = with_ctx("jedi", 1, |ctx| {
            ctx.freq_mhz = Some(990.0);
            run_command("logmap --workload 8 --intensity 4", ctx).runtime_s
        });
        assert!(throttled > 1.3 * nominal, "{throttled} vs {nominal}");
    }

    #[test]
    fn rejects_bad_args() {
        with_ctx("jedi", 1, |ctx| {
            let out = run_command("logmap --workload 0 --intensity 1", ctx);
            assert!(!out.success);
        });
    }

    #[test]
    fn pjrt_validation_when_artifacts_present() {
        let dir = crate::runtime::manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let Ok(mut engine) = crate::runtime::Engine::load_default() else {
            crate::obs_warn!("skipped: engine backend unavailable");
            return;
        };
        super::super::testutil::with_ctx_engine("jedi", 1, Some(&mut engine), |ctx| {
            let cmd = CmdLine::parse("logmap --workload 2 --intensity 0.5").unwrap();
            let out = run(&cmd, ctx);
            assert!(out.success);
            assert_eq!(out.metrics.str_of("validation"), Some("pjrt"));
            assert!(out.metrics.f64_of("host_wall_ms").unwrap() > 0.0);
        });
    }
}
