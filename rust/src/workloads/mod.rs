//! The benchmark-application zoo (DESIGN.md §2 substrate).
//!
//! Every application the paper's experiments run is implemented here and
//! dispatched by command line — the harness's `do:` steps call e.g.
//! `logmap --workload 6 --intensity 2.4` and the executor routes it to
//! [`logmap`]. Four real benchmarks (logmap and BabelStream backed by
//! actual PJRT execution of the AOT kernels; Graph500 running a real BFS;
//! OSU from the analytic network model) plus a parameterised scalable
//! application ([`scalable`]) that populates the 72-entry JUREAP-like
//! portfolio ([`portfolio`]).

pub mod calibration;
pub mod chaos;
pub mod graph500;
pub mod logmap;
pub mod onboarding;
pub mod osu;
pub mod portfolio;
pub mod regression;
pub mod scalable;
pub mod stream;

pub use calibration::HostCalibration;

use std::collections::BTreeMap;

use crate::cluster::RunEnv;
use crate::util::json::Json;
use crate::util::prng::Prng;

/// Workload phase profile used by the energy launcher (Fig. 8/9):
/// utilisation during the steady phase and the memory-bound fraction
/// that shapes the frequency response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AppProfile {
    pub utilization: f64,
    pub mem_bound: f64,
}

impl Default for AppProfile {
    fn default() -> Self {
        AppProfile {
            utilization: 0.9,
            mem_bound: 0.5,
        }
    }
}

/// Everything an application sees when it runs inside a batch job.
pub struct ExecCtx<'a> {
    pub env: &'a RunEnv<'a>,
    pub nodes: u64,
    pub tasks_per_node: u64,
    pub threads_per_task: u64,
    /// Environment variables (feature injection lands here, e.g.
    /// `UCX_RNDV_THRESH`).
    pub env_vars: BTreeMap<String, String>,
    /// GPU core clock override [MHz] (energy studies); None = nominal.
    pub freq_mhz: Option<f64>,
    pub calibration: HostCalibration,
    pub rng: &'a mut Prng,
    /// PJRT engine when artifacts are built; apps validate through it.
    pub engine: Option<&'a mut crate::runtime::Engine>,
}

impl<'a> ExecCtx<'a> {
    /// Effective clock for this run [MHz].
    pub fn freq(&self) -> f64 {
        self.freq_mhz
            .unwrap_or(self.env.machine.power.nominal_mhz)
    }

    /// Frequency-dependent throughput factor for a given profile.
    pub fn freq_perf(&self, profile: AppProfile) -> f64 {
        self.env
            .machine
            .power
            .perf_factor(self.freq(), profile.mem_bound)
    }

    /// Total GPUs participating in this run.
    pub fn total_gpus(&self) -> u64 {
        self.nodes * self.env.machine.gpus_per_node
    }
}

/// What an application run produced.
#[derive(Debug, Clone)]
pub struct AppOutput {
    pub runtime_s: f64,
    pub success: bool,
    pub metrics: Json,
    pub files: Vec<(String, String)>,
    pub profile: AppProfile,
}

impl AppOutput {
    pub fn failure(msg: &str) -> AppOutput {
        AppOutput {
            runtime_s: 0.0,
            success: false,
            metrics: Json::obj().set("error", msg),
            files: Vec::new(),
            profile: AppProfile::default(),
        }
    }
}

/// Parsed command line: binary + positional args + `--flag value` pairs.
#[derive(Debug, Clone, PartialEq)]
pub struct CmdLine {
    pub binary: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl CmdLine {
    pub fn parse(line: &str) -> Option<CmdLine> {
        let mut parts = line.split_whitespace();
        let binary = parts.next()?.to_string();
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let rest: Vec<&str> = parts.collect();
        let mut i = 0;
        while i < rest.len() {
            if let Some(name) = rest[i].strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                    i += 1;
                } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), rest[i + 1].to_string());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(rest[i].to_string());
                i += 1;
            }
        }
        Some(CmdLine {
            binary,
            flags,
            positional,
        })
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> f64 {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> u64 {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag_str(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }
}

/// Dispatch a command line to the owning application.
///
/// Non-application shell commands (cmake, export, module, mkdir, …) are
/// treated as instant no-op successes on the login node, matching how a
/// real harness step list mixes setup commands with the launch line.
pub fn run_command(line: &str, ctx: &mut ExecCtx) -> AppOutput {
    let Some(cmd) = CmdLine::parse(line) else {
        return AppOutput {
            runtime_s: 0.0,
            success: true,
            metrics: Json::obj(),
            files: Vec::new(),
            profile: AppProfile::default(),
        };
    };
    let bin = cmd
        .binary
        .rsplit('/')
        .next()
        .unwrap_or(&cmd.binary)
        .to_string();
    match bin.as_str() {
        "logmap" => logmap::run(&cmd, ctx),
        "babelstream" | "stream" => stream::run(&cmd, ctx),
        "graph500" => graph500::run(&cmd, ctx),
        "osu_bw" | "osu_latency" => osu::run(&cmd, ctx),
        "simapp" => scalable::run(&cmd, ctx),
        // login-node setup commands succeed instantly
        "cmake" | "make" | "module" | "export" | "mkdir" | "cp" | "echo" | "cd"
        | "source" | "true" => AppOutput {
            runtime_s: 0.0,
            success: true,
            metrics: Json::obj(),
            files: Vec::new(),
            profile: AppProfile::default(),
        },
        other => AppOutput::failure(&format!("unknown application '{other}'")),
    }
}

/// Whether `name` is a binary the dispatch table above can execute.
/// Engine definitions (DESIGN.md §15) are validated against this at load
/// time so a typo'd command fails `exacb measure --validate-only`, not a
/// campaign three days in.
pub fn known_binary(name: &str) -> bool {
    matches!(
        name,
        "logmap"
            | "babelstream"
            | "stream"
            | "graph500"
            | "osu_bw"
            | "osu_latency"
            | "simapp"
            | "cmake"
            | "make"
            | "module"
            | "export"
            | "mkdir"
            | "cp"
            | "echo"
            | "cd"
            | "source"
            | "true"
    )
}

/// Extract an environment variable that may be injected as an
/// `export`-style command (feature injection, §V-A.3). Supports both the
/// plain form `UCX_RNDV_THRESH=65536` and the scoped UCX form
/// `UCX_RNDV_THRESH=intra:65536,inter:65536` (the `inter` value wins for
/// the inter-node benchmarks).
pub fn parse_rndv_thresh(env_vars: &BTreeMap<String, String>, default: u64) -> u64 {
    let Some(raw) = env_vars.get("UCX_RNDV_THRESH") else {
        return default;
    };
    if let Ok(v) = raw.parse::<u64>() {
        return v;
    }
    for part in raw.split(',') {
        let part = part.trim();
        if let Some(v) = part.strip_prefix("inter:") {
            if let Ok(v) = v.parse() {
                return v;
            }
        }
    }
    // fall back to the first scoped value
    for part in raw.split(',') {
        if let Some((_, v)) = part.split_once(':') {
            if let Ok(v) = v.parse() {
                return v;
            }
        }
    }
    default
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::cluster::{Cluster, SoftwareStage};
    use crate::util::timeutil::SimTime;

    pub fn with_ctx<R>(machine: &str, nodes: u64, f: impl FnOnce(&mut ExecCtx) -> R) -> R {
        with_ctx_engine(machine, nodes, None, f)
    }

    pub fn with_ctx_engine<R>(
        machine: &str,
        nodes: u64,
        engine: Option<&mut crate::runtime::Engine>,
        f: impl FnOnce(&mut ExecCtx) -> R,
    ) -> R {
        let cluster = Cluster::standard();
        let stage = SoftwareStage::stage_2026();
        let env = cluster.env_at(machine, &stage, SimTime::from_days(5)).unwrap();
        let mut rng = Prng::new(7);
        let mut ctx = ExecCtx {
            env: &env,
            nodes,
            tasks_per_node: 4,
            threads_per_task: 8,
            env_vars: BTreeMap::new(),
            freq_mhz: None,
            calibration: HostCalibration::default(),
            rng: &mut rng,
            engine,
        };
        f(&mut ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cmdline_parsing() {
        let c = CmdLine::parse("logmap --workload 6 --intensity 2.4").unwrap();
        assert_eq!(c.binary, "logmap");
        assert_eq!(c.flag_u64("workload", 0), 6);
        assert!((c.flag_f64("intensity", 0.0) - 2.4).abs() < 1e-12);
        let c = CmdLine::parse("graph500 run --scale=16 --validate").unwrap();
        assert_eq!(c.flag_u64("scale", 0), 16);
        assert_eq!(c.flag_str("validate"), Some("true"));
        assert_eq!(c.positional, vec!["run"]);
        assert!(CmdLine::parse("   ").is_none());
    }

    #[test]
    fn setup_commands_are_noops() {
        testutil::with_ctx("jedi", 1, |ctx| {
            let out = run_command("cmake -S . -B build", ctx);
            assert!(out.success);
            assert_eq!(out.runtime_s, 0.0);
        });
    }

    #[test]
    fn unknown_binary_fails() {
        testutil::with_ctx("jedi", 1, |ctx| {
            let out = run_command("./mystery-app --x 1", ctx);
            assert!(!out.success);
        });
    }

    #[test]
    fn rndv_thresh_parsing() {
        let mut env = BTreeMap::new();
        assert_eq!(parse_rndv_thresh(&env, 8192), 8192);
        env.insert("UCX_RNDV_THRESH".into(), "65536".into());
        assert_eq!(parse_rndv_thresh(&env, 8192), 65536);
        env.insert(
            "UCX_RNDV_THRESH".into(),
            "intra:1024,inter:262144".into(),
        );
        assert_eq!(parse_rndv_thresh(&env, 8192), 262144);
        env.insert("UCX_RNDV_THRESH".into(), "intra:4096".into());
        assert_eq!(parse_rndv_thresh(&env, 8192), 4096);
        env.insert("UCX_RNDV_THRESH".into(), "garbage".into());
        assert_eq!(parse_rndv_thresh(&env, 8192), 8192);
    }
}
