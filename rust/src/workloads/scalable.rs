//! Parameterised scalable application: the generic performance model
//! behind the JUREAP portfolio and the scaling figures (Figs. 5 & 7).
//!
//! `simapp --flops F --serial 0.05 --membound 0.5 --comm-mb 64 --steps 50
//!         [--weak]`
//!
//! Runtime model per run on machine M with N nodes:
//!
//! ```text
//! T = serial + parallel_compute / (N·G·rate) + steps · allreduce(comm, N·G)
//! rate = peak(M) · mix-efficiency(membound) · stage/event factors · f(freq)
//! weak scaling: total work scales with N (per-node work constant)
//! ```
//!
//! This is the standard Amdahl + collective-overhead decomposition; it
//! produces the strong-scaling roll-off with 80%-band crossings of
//! Fig. 5 and the weak-scaling efficiency decay of Fig. 7.

use super::{AppOutput, AppProfile, CmdLine, ExecCtx};
use crate::cluster::MetricClass;
use crate::util::json::Json;

/// Model parameters of one synthetic application.
#[derive(Debug, Clone, PartialEq)]
pub struct AppModel {
    pub name: String,
    /// Total useful work at reference size [GFLOP].
    pub gflops_total: f64,
    /// Amdahl serial fraction.
    pub serial_frac: f64,
    /// Memory-bound fraction (shapes rate + frequency response).
    pub mem_bound: f64,
    /// Bytes all-reduced per step [MB].
    pub comm_mb: f64,
    /// Communication steps per run.
    pub steps: u64,
    /// Weak scaling: per-node work is constant.
    pub weak: bool,
}

impl Default for AppModel {
    fn default() -> Self {
        AppModel {
            name: "simapp".into(),
            gflops_total: 50_000.0,
            serial_frac: 0.02,
            mem_bound: 0.5,
            comm_mb: 32.0,
            steps: 50,
            weak: false,
        }
    }
}

impl AppModel {
    /// Build the performance model from a loaded app definition's
    /// parameter table (DESIGN.md §15).
    pub fn from_def(def: &crate::defs::AppDef) -> AppModel {
        AppModel {
            name: def.name.clone(),
            gflops_total: def.gflops_total,
            serial_frac: def.serial_frac,
            mem_bound: def.mem_bound,
            comm_mb: def.comm_mb,
            steps: def.steps,
            weak: def.weak,
        }
    }

    pub fn from_cmd(cmd: &CmdLine) -> AppModel {
        AppModel {
            name: cmd
                .flag_str("name")
                .unwrap_or("simapp")
                .to_string(),
            gflops_total: cmd.flag_f64("flops", 50_000.0),
            serial_frac: cmd.flag_f64("serial", 0.02).clamp(0.0, 1.0),
            mem_bound: cmd.flag_f64("membound", 0.5).clamp(0.0, 1.0),
            comm_mb: cmd.flag_f64("comm-mb", 32.0).max(0.0),
            steps: cmd.flag_u64("steps", 50),
            weak: cmd.flag_str("weak").is_some(),
        }
    }

    pub fn profile(&self) -> AppProfile {
        AppProfile {
            utilization: 0.95 - 0.25 * self.mem_bound,
            mem_bound: self.mem_bound,
        }
    }

    /// Effective per-GPU rate [GFLOP/s] on this machine/env/frequency.
    pub fn rate_per_gpu(&self, ctx: &ExecCtx) -> f64 {
        let m = ctx.env.machine;
        // mix efficiency: compute-bound work near FP32 peak fraction,
        // memory-bound work at the bandwidth-derived rate (1 flop / 8 B).
        let compute_rate = m.gpu_gen.peak_tflops() * 1000.0 * 0.30;
        let membw_rate = m.gpu_gen.hbm_bw_gbs() / 8.0;
        let mixed = 1.0
            / ((1.0 - self.mem_bound) / compute_rate + self.mem_bound / membw_rate);
        mixed
            * ctx.env.factor(MetricClass::Compute).min(ctx.env.factor(MetricClass::MemBw))
            * ctx.freq_perf(self.profile())
    }

    /// Modelled runtime [s] for this context (no noise).
    pub fn runtime_s(&self, ctx: &ExecCtx) -> f64 {
        let gpus = ctx.total_gpus() as f64;
        let work = if self.weak {
            self.gflops_total * ctx.nodes as f64
        } else {
            self.gflops_total
        };
        let rate = self.rate_per_gpu(ctx);
        // Serial (non-scalable) portion: defined on the *reference* size —
        // under weak scaling each node's serial work runs concurrently.
        let serial = self.serial_frac * self.gflops_total / rate;
        let parallel = (1.0 - self.serial_frac) * work / (gpus * rate);
        let comm = self.steps as f64
            * ctx
                .env
                .machine
                .network
                .allreduce_time_us((self.comm_mb * 1e6) as u64, gpus as u64)
            / 1e6
            / ctx.env.factor(MetricClass::Network);
        serial + parallel + comm + 1.0 // + init/teardown
    }
}

pub fn run(cmd: &CmdLine, ctx: &mut ExecCtx) -> AppOutput {
    let model = AppModel::from_cmd(cmd);
    let base = model.runtime_s(ctx);
    let runtime_s = base * ctx.env.noise(ctx.rng);
    let gpus = ctx.total_gpus() as f64;
    let work = if model.weak {
        model.gflops_total * ctx.nodes as f64
    } else {
        model.gflops_total
    };
    let metrics = Json::obj()
        .set("app", model.name.as_str())
        .set("tts", runtime_s)
        .set("gflops_rate", work / runtime_s)
        .set("per_gpu_gflops", work / runtime_s / gpus)
        .set("mem_bound", model.mem_bound)
        .set(
            "scaling_mode",
            if model.weak { "weak" } else { "strong" },
        );
    let out = format!(
        "{} completed\nwork: {work:.1} GFLOP\ntime: {runtime_s:.4}\n",
        model.name
    );
    AppOutput {
        runtime_s,
        success: true,
        metrics,
        files: vec![("app.out".into(), out)],
        profile: model.profile(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::with_ctx;
    use super::super::{run_command, CmdLine};
    use super::*;

    fn model_runtime(machine: &str, nodes: u64, extra: &str) -> f64 {
        with_ctx(machine, nodes, |ctx| {
            let cmd = CmdLine::parse(&format!("simapp --flops 200000 {extra}")).unwrap();
            AppModel::from_cmd(&cmd).runtime_s(ctx)
        })
    }

    #[test]
    fn strong_scaling_rolls_off() {
        // speedup grows but efficiency decays with node count (Fig. 5)
        let t1 = model_runtime("juwels-booster", 1, "--comm-mb 64 --steps 100");
        let t4 = model_runtime("juwels-booster", 4, "--comm-mb 64 --steps 100");
        let t32 = model_runtime("juwels-booster", 32, "--comm-mb 64 --steps 100");
        let s4 = t1 / t4;
        let s32 = t1 / t32;
        assert!(s4 > 2.8 && s4 <= 4.0, "s4={s4}");
        assert!(s32 > 8.0 && s32 < 28.0, "s32={s32}");
        let eff32 = s32 / 32.0;
        assert!(eff32 < 0.85, "efficiency must roll off: {eff32}");
    }

    #[test]
    fn weak_scaling_efficiency_decays_gently() {
        let t1 = model_runtime("jedi", 1, "--weak --comm-mb 64 --steps 100");
        let t16 = model_runtime("jedi", 16, "--weak --comm-mb 64 --steps 100");
        let eff = t1 / t16;
        assert!(eff > 0.60 && eff < 1.0, "weak efficiency={eff}");
    }

    #[test]
    fn generational_speedup_for_compute_bound() {
        let ampere = model_runtime("juwels-booster", 4, "--membound 0.2");
        let hopper = model_runtime("jedi", 4, "--membound 0.2");
        assert!(ampere / hopper > 2.0, "{ampere} vs {hopper}");
    }

    #[test]
    fn stage_2025_is_slower() {
        use crate::cluster::{Cluster, SoftwareStage};
        use crate::util::timeutil::SimTime;
        let cluster = Cluster::standard();
        let run_stage = |stage: &SoftwareStage| {
            let env = cluster.env_at("jedi", stage, SimTime(0)).unwrap();
            let mut rng = crate::util::prng::Prng::new(1);
            let ctx = super::super::ExecCtx {
                env: &env,
                nodes: 8,
                tasks_per_node: 4,
                threads_per_task: 8,
                env_vars: Default::default(),
                freq_mhz: None,
                calibration: Default::default(),
                rng: &mut rng,
                engine: None,
            };
            AppModel {
                comm_mb: 128.0,
                steps: 200,
                ..Default::default()
            }
            .runtime_s(&ctx)
        };
        let t2026 = run_stage(&SoftwareStage::stage_2026());
        let t2025 = run_stage(&SoftwareStage::stage_2025());
        assert!(t2025 > 1.03 * t2026, "{t2025} vs {t2026}");
    }

    #[test]
    fn app_runs_and_reports() {
        with_ctx("jedi", 2, |ctx| {
            let out = run_command("simapp --name neuroflow --flops 10000", ctx);
            assert!(out.success);
            assert_eq!(out.metrics.str_of("app"), Some("neuroflow"));
            assert!(out.metrics.f64_of("tts").unwrap() > 0.0);
        });
    }

    #[test]
    fn membound_lowers_sweet_spot_profile() {
        let cmd = CmdLine::parse("simapp --membound 0.9").unwrap();
        let m = AppModel::from_cmd(&cmd);
        assert!(m.profile().mem_bound > 0.8);
        assert!(m.profile().utilization < 0.8);
    }
}
