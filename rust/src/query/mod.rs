//! The a-posteriori query layer over digest-indexed snapshots
//! (DESIGN.md §12).
//!
//! The paper's benchmark collections exist to be *queried*: "is this
//! commit slower than that one?", "which machine wins on this workload
//! portfolio?". This module answers those questions from
//! [`crate::store::Snapshot`] row sets — reports were parsed once at
//! snapshot build time, refreshes pay O(delta), and the snapshot is
//! immutable while readers hold it, so the aggregation itself fans out
//! across threads ([`crate::store::fan_chunks`]):
//!
//! * [`cmp`] — pairwise engine comparison with Welch confidence
//!   intervals on the difference of means ([`crate::tracking::stats`])
//!   and a geometric-mean speedup, behind `exacb cmp`;
//! * [`rank`] — rebar-style rank aggregation: per-workload competition
//!   ranks flattened into mean rank + geomean ratio-to-best, behind
//!   `exacb rank`;
//! * [`export`] — portable JSON/CSV row export carrying full
//!   provenance (commit SHA, machine, seed, pipeline, date), in the
//!   github-action-benchmark convention.
//!
//! Everything here is a pure function of a `&[Row]` slice in the
//! canonical [`crate::store::sort_rows`] order, so results are
//! independent of ingestion order and of the shard count
//! (property-tested): shard-local partial aggregates are merged in
//! shard order, which reproduces the sequential fold bit-for-bit —
//! including floating-point sums.

pub mod cmp;
pub mod export;
pub mod rank;

pub use cmp::{compare, CmpReport, CmpRow};
pub use export::{rows_to_csv, rows_to_json};
pub use rank::{rank, AggregateRank, RankReport, RankedEngine, WorkloadRanking};

use crate::coordinator::World;
use crate::store::{sort_rows, Row};
use std::collections::BTreeMap;

/// What a comparison or ranking treats as the competing unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Compare recording machines (cross-system queries).
    Machine,
    /// Compare source-commit SHAs (longitudinal queries).
    Commit,
}

impl Engine {
    /// The row field this engine axis reads.
    pub fn of<'a>(&self, row: &'a Row) -> &'a str {
        match self {
            Engine::Machine => &row.machine,
            Engine::Commit => &row.commit,
        }
    }
}

/// Strip the execution component's `{machine}.` store-prefix from an
/// app label so the *same workload* recorded on different machines
/// groups together (`jedi.stream` and `jupiter.stream` → `stream`).
pub fn base_app<'a>(app: &'a str, machine: &str) -> &'a str {
    app.strip_prefix(machine)
        .and_then(|rest| rest.strip_prefix('.'))
        .unwrap_or(app)
}

/// Every recorded observation across every repository in the world, in
/// canonical order. Each repo is read through its shared snapshot, so
/// repeated queries pay O(delta since the last reader).
pub fn world_rows(world: &World) -> Vec<Row> {
    let mut rows = Vec::new();
    for repo in world.repos.values() {
        rows.extend(repo.with_snapshot(|snap| snap.rows()));
    }
    sort_rows(&mut rows);
    rows
}

/// Distinct commit SHAs ordered by the earliest time each was observed
/// (ties broken by SHA). `exacb cmp --by commit` uses first/last as
/// the baseline/candidate pair; the integration tests use it to name
/// the pre-/post-injection commits of a planted regression.
pub fn commits_by_first_seen(rows: &[Row]) -> Vec<String> {
    let mut first: BTreeMap<&str, crate::util::timeutil::SimTime> = BTreeMap::new();
    for r in rows {
        let e = first.entry(&r.commit).or_insert(r.time);
        if r.time < *e {
            *e = r.time;
        }
    }
    let mut order: Vec<(crate::util::timeutil::SimTime, &str)> =
        first.into_iter().map(|(c, t)| (t, c)).collect();
    order.sort();
    order.into_iter().map(|(_, c)| c.to_string()).collect()
}

/// Shard-parallel grouping: fold `rows` into per-key `Vec<f64>` groups
/// on every shard, then merge the shard-local maps **in shard order**.
/// Chunks partition the slice in order, so per-key concatenation
/// reproduces the sequential push order exactly — grouped values (and
/// therefore every downstream floating-point fold) are bit-identical
/// for any shard count.
pub(crate) fn group_values<K: Ord + Send>(
    rows: &[Row],
    shards: usize,
    key_of: impl Fn(&Row) -> Option<K> + Sync,
) -> BTreeMap<K, Vec<f64>> {
    let partials = crate::store::fan_chunks(rows, shards, |chunk| {
        let mut m: BTreeMap<K, Vec<f64>> = BTreeMap::new();
        for r in chunk {
            if let Some(k) = key_of(r) {
                m.entry(k).or_default().push(r.value);
            }
        }
        m
    });
    let mut merged: BTreeMap<K, Vec<f64>> = BTreeMap::new();
    for part in partials {
        for (k, vs) in part {
            merged.entry(k).or_default().extend(vs);
        }
    }
    merged
}

#[cfg(test)]
pub(crate) fn synthetic_row(
    app: &str,
    machine: &str,
    metric: &str,
    nodes: u64,
    day: i64,
    commit: &str,
    value: f64,
) -> Row {
    Row {
        app: format!("{machine}.{app}"),
        machine: machine.to_string(),
        metric: metric.to_string(),
        nodes,
        time: crate::util::timeutil::SimTime::from_days(day),
        pipeline_id: 1,
        commit: commit.to_string(),
        seed: 7,
        digest: crate::util::wide_hash(
            format!("{app}|{machine}|{metric}|{nodes}|{day}|{commit}|{value}").as_bytes(),
        ),
        value,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_app_strips_only_its_own_machine_prefix() {
        assert_eq!(base_app("jedi.stream", "jedi"), "stream");
        assert_eq!(base_app("jedi.stream", "jupiter"), "jedi.stream");
        assert_eq!(base_app("stream", "jedi"), "stream");
        // a machine name that is a plain prefix (no dot) must not match
        assert_eq!(base_app("jediXstream", "jedi"), "jediXstream");
    }

    #[test]
    fn commits_ordered_by_first_observation() {
        let rows = vec![
            synthetic_row("a", "m", "runtime", 1, 5, "ccc", 1.0),
            synthetic_row("a", "m", "runtime", 1, 1, "bbb", 1.0),
            synthetic_row("a", "m", "runtime", 1, 3, "bbb", 1.0),
            synthetic_row("a", "m", "runtime", 1, 2, "aaa", 1.0),
        ];
        assert_eq!(commits_by_first_seen(&rows), vec!["bbb", "aaa", "ccc"]);
    }

    #[test]
    fn grouping_is_shard_count_independent() {
        let mut rows = Vec::new();
        for i in 0..97i64 {
            rows.push(synthetic_row(
                if i % 3 == 0 { "a" } else { "b" },
                "m",
                "runtime",
                1 + (i % 4) as u64,
                i,
                "c0",
                0.1 + i as f64 * 0.01,
            ));
        }
        let seq = group_values(&rows, 1, |r| Some((r.app.clone(), r.nodes)));
        for shards in [2, 3, 8, 200] {
            let par = group_values(&rows, shards, |r| Some((r.app.clone(), r.nodes)));
            assert_eq!(seq, par, "shards={shards}");
        }
    }
}
