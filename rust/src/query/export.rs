//! Portable row export (DESIGN.md §12).
//!
//! `exacb cmp`/`exacb rank` can dump the exact row set a query ran
//! over, so external dashboards reproduce the verdicts from the same
//! data. JSON follows the github-action-benchmark convention — an
//! array of `{name, unit, value, extra}` points where `extra` carries
//! full provenance (machine, commit SHA, seed, pipeline, date,
//! observation digest); CSV is one flat provenance-first table.

use crate::store::Row;
use crate::util::json::Json;

/// Measurement unit for a metric name; empty when unknown (external
/// consumers treat the value as dimensionless).
pub fn unit_for(metric: &str) -> &'static str {
    match metric {
        "runtime" => "s",
        "energy_j" => "J",
        "edp" => "Js",
        "power_w" => "W",
        _ => "",
    }
}

/// Export rows as a github-action-benchmark style JSON array. Rows are
/// emitted in input order, so a canonical row set exports canonically.
pub fn rows_to_json(rows: &[Row]) -> Json {
    let mut arr = Json::arr();
    for r in rows {
        arr.push(
            Json::obj()
                .set(
                    "name",
                    format!("{}/{}@{}n/{}", r.app, r.metric, r.nodes, r.machine),
                )
                .set("unit", unit_for(&r.metric))
                .set("value", r.value)
                .set(
                    "extra",
                    Json::obj()
                        .set("machine", r.machine.as_str())
                        .set("commit", r.commit.as_str())
                        .set("seed", r.seed)
                        .set("pipeline", r.pipeline_id)
                        .set("nodes", r.nodes)
                        .set("date", r.time.date_string())
                        .set("digest", r.digest.as_str()),
                ),
        );
    }
    arr
}

/// Header of the flat CSV export, provenance first.
pub const EXPORT_COLUMNS: [&str; 9] = [
    "app", "machine", "metric", "nodes", "pipeline", "commit", "seed", "date", "value",
];

/// Export rows as one flat CSV table (header + one line per row, input
/// order). Values render with enough precision to round-trip f64.
pub fn rows_to_csv(rows: &[Row]) -> String {
    let mut out = EXPORT_COLUMNS.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{:?}\n",
            r.app,
            r.machine,
            r.metric,
            r.nodes,
            r.pipeline_id,
            r.commit,
            r.seed,
            r.time.date_string(),
            r.value
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::synthetic_row;
    use super::*;

    #[test]
    fn json_round_trips_with_full_provenance() {
        let rows = vec![
            synthetic_row("stream", "jedi", "runtime", 4, 3, "abc123", 1.5),
            synthetic_row("stream", "jedi", "energy_j", 4, 3, "abc123", 250.0),
        ];
        let doc = rows_to_json(&rows);
        let parsed = Json::parse(&doc.pretty()).unwrap();
        let pts = parsed.as_arr().unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(
            pts[0].str_of("name").unwrap(),
            "jedi.stream/runtime@4n/jedi"
        );
        assert_eq!(pts[0].str_of("unit"), Some("s"));
        assert_eq!(pts[1].str_of("unit"), Some("J"));
        let extra = pts[0].get("extra").unwrap();
        assert_eq!(extra.str_of("commit"), Some("abc123"));
        assert_eq!(extra.u64_of("seed"), Some(7));
        assert_eq!(extra.u64_of("nodes"), Some(4));
        assert_eq!(extra.str_of("date"), Some("2026-01-04"));
        assert_eq!(extra.str_of("digest").map(str::len), Some(32));
    }

    #[test]
    fn csv_has_the_documented_header_and_roundtrip_values() {
        let rows = vec![synthetic_row("a", "m", "bw", 1, 0, "c0", 0.1 + 0.2)];
        let csv = rows_to_csv(&rows);
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), EXPORT_COLUMNS.join(","));
        let data = lines.next().unwrap();
        let cols: Vec<&str> = data.split(',').collect();
        assert_eq!(cols.len(), EXPORT_COLUMNS.len());
        assert_eq!(cols[0], "m.a");
        // {:?} prints the shortest representation that parses back to
        // the same f64 — exports never lose precision
        assert_eq!(cols[8].parse::<f64>().unwrap(), 0.1 + 0.2);
        assert!(lines.next().is_none());
    }

    #[test]
    fn unknown_metrics_export_dimensionless() {
        assert_eq!(unit_for("bananas_per_joule"), "");
        assert_eq!(unit_for("edp"), "Js");
    }
}
