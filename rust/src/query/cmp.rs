//! Pairwise engine comparison with Welch confidence intervals
//! (`exacb cmp`, DESIGN.md §12).
//!
//! Given a canonical row set, a baseline and a candidate engine label
//! (two machines, or two source commits), every shared
//! (workload, metric, nodes) group gets a speedup ratio and a Welch
//! interval on the difference of means ([`crate::tracking::stats`]);
//! the report's verdict per group is `faster` / `slower` /
//! `indistinguishable` / `insufficient`, lower-is-better. Grouping
//! fans out across shards ([`super::group_values`]), so comparing a
//! large collection parallelises while staying bit-identical to the
//! sequential fold.

use super::{base_app, group_values, Engine};
use crate::store::{fan_shards, Row};
use crate::tracking::stats::{welch_interval, ConfInterval};
use crate::util::table::Table;

/// One compared (workload, metric, nodes) group.
#[derive(Debug, Clone)]
pub struct CmpRow {
    /// Workload label (store app with the machine prefix stripped).
    pub app: String,
    /// Metric name (lower-is-better convention).
    pub metric: String,
    /// Node count.
    pub nodes: u64,
    /// Sample counts on each side.
    pub n_baseline: usize,
    pub n_candidate: usize,
    /// Mean metric value on each side.
    pub mean_baseline: f64,
    pub mean_candidate: f64,
    /// `mean_baseline / mean_candidate` — > 1 means the candidate is
    /// faster (lower-is-better).
    pub speedup: f64,
    /// Welch interval on `mean(candidate) − mean(baseline)`; `None`
    /// when either side has fewer than two samples.
    pub interval: Option<ConfInterval>,
    /// `faster` / `slower` / `indistinguishable` / `insufficient`.
    pub verdict: &'static str,
}

/// The full comparison: per-group rows plus collection-wide summary.
#[derive(Debug, Clone)]
pub struct CmpReport {
    /// Engine axis the labels come from.
    pub engine: Engine,
    pub baseline: String,
    pub candidate: String,
    pub confidence: f64,
    /// One row per (workload, metric, nodes) group present on *both*
    /// sides, in group order.
    pub rows: Vec<CmpRow>,
    /// Groups observed on only one side (coverage gaps are findings,
    /// not silent drops).
    pub only_baseline: usize,
    pub only_candidate: usize,
}

impl CmpReport {
    pub fn count(&self, verdict: &str) -> usize {
        self.rows.iter().filter(|r| r.verdict == verdict).count()
    }

    /// Geometric mean of the finite positive per-group speedups — the
    /// collection-wide headline number (> 1: candidate faster overall).
    pub fn geomean_speedup(&self) -> Option<f64> {
        let lns: Vec<f64> = self
            .rows
            .iter()
            .map(|r| r.speedup)
            .filter(|s| s.is_finite() && *s > 0.0)
            .map(f64::ln)
            .collect();
        if lns.is_empty() {
            return None;
        }
        Some((lns.iter().sum::<f64>() / lns.len() as f64).exp())
    }

    /// Render the per-group comparison as a table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "workload", "metric", "nodes", "n", "baseline", "candidate", "speedup", "ci_lo",
            "ci_hi", "verdict",
        ]);
        if self.rows.is_empty() {
            t.push_placeholder("(no shared workload groups)");
            return t;
        }
        for r in &self.rows {
            t.push_row(vec![
                r.app.clone(),
                r.metric.clone(),
                r.nodes.to_string(),
                format!("{}/{}", r.n_baseline, r.n_candidate),
                format!("{:.4}", r.mean_baseline),
                format!("{:.4}", r.mean_candidate),
                format!("{:.3}", r.speedup),
                r.interval
                    .as_ref()
                    .map(|i| format!("{:+.4}", i.lo))
                    .unwrap_or_else(|| "-".to_string()),
                r.interval
                    .as_ref()
                    .map(|i| format!("{:+.4}", i.hi))
                    .unwrap_or_else(|| "-".to_string()),
                r.verdict.to_string(),
            ]);
        }
        t
    }
}

/// Compare `candidate` against `baseline` along the `engine` axis over
/// a canonical row set. Groups are keyed by (workload, metric, nodes);
/// `shards` bounds the fan-out (1 = sequential; results are identical
/// either way, property-tested).
pub fn compare(
    rows: &[Row],
    engine: Engine,
    baseline: &str,
    candidate: &str,
    confidence: f64,
    shards: usize,
) -> CmpReport {
    // one sharded grouping pass; the side tag is part of the key so a
    // single merge yields both sides in group order
    let grouped = group_values(rows, shards, |r| {
        let label = engine.of(r);
        let side = if label == baseline {
            false
        } else if label == candidate {
            true
        } else {
            return None;
        };
        let app = match engine {
            Engine::Machine => base_app(&r.app, &r.machine).to_string(),
            Engine::Commit => r.app.clone(),
        };
        Some(((app, r.metric.clone(), r.nodes), side))
    });
    // pair the sides back up per (app, metric, nodes)
    let mut pairs: std::collections::BTreeMap<(String, String, u64), (Vec<f64>, Vec<f64>)> =
        std::collections::BTreeMap::new();
    for ((key, side), vs) in grouped {
        let slot = pairs.entry(key).or_default();
        if side {
            slot.1 = vs;
        } else {
            slot.0 = vs;
        }
    }
    let mut only_baseline = 0;
    let mut only_candidate = 0;
    let shared: Vec<((String, String, u64), (Vec<f64>, Vec<f64>))> = pairs
        .into_iter()
        .filter(|(_, (b, c))| {
            if b.is_empty() {
                only_candidate += 1;
            }
            if c.is_empty() {
                only_baseline += 1;
            }
            !b.is_empty() && !c.is_empty()
        })
        .collect();
    // per-group statistics fan out too; fan_shards preserves item order
    let rows = fan_shards(&shared, shards, |((app, metric, nodes), (base, cand))| {
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let mb = mean(base);
        let mc = mean(cand);
        let interval = welch_interval(base, cand, confidence);
        let verdict = match &interval {
            // interval is on mean(candidate) − mean(baseline): entirely
            // above zero = candidate takes longer = slower
            Some(i) if i.entirely_above(0.0) => "slower",
            Some(i) if i.entirely_below(0.0) => "faster",
            Some(_) => "indistinguishable",
            None => "insufficient",
        };
        CmpRow {
            app: app.clone(),
            metric: metric.clone(),
            nodes: *nodes,
            n_baseline: base.len(),
            n_candidate: cand.len(),
            mean_baseline: mb,
            mean_candidate: mc,
            speedup: mb / mc,
            interval,
            verdict,
        }
    });
    CmpReport {
        engine,
        baseline: baseline.to_string(),
        candidate: candidate.to_string(),
        confidence,
        rows,
        only_baseline,
        only_candidate,
    }
}

#[cfg(test)]
mod tests {
    use super::super::synthetic_row;
    use super::*;

    /// 8 repeats per side; candidate 20% faster on `a`, identical on
    /// `b`, only-baseline on `c`.
    fn fixture() -> Vec<Row> {
        let mut rows = Vec::new();
        for i in 0..8i64 {
            let jitter = i as f64 * 0.003;
            rows.push(synthetic_row("a", "base", "runtime", 1, i, "c0", 10.0 + jitter));
            rows.push(synthetic_row("a", "cand", "runtime", 1, i, "c0", 8.0 + jitter));
            rows.push(synthetic_row("b", "base", "runtime", 2, i, "c0", 5.0 + jitter));
            rows.push(synthetic_row("b", "cand", "runtime", 2, i, "c0", 5.0 + jitter));
            rows.push(synthetic_row("c", "base", "runtime", 1, i, "c0", 1.0));
        }
        rows
    }

    #[test]
    fn detects_faster_and_indistinguishable_groups() {
        let report = compare(&fixture(), Engine::Machine, "base", "cand", 0.95, 1);
        assert_eq!(report.rows.len(), 2);
        assert_eq!(report.only_baseline, 1);
        assert_eq!(report.only_candidate, 0);
        let a = &report.rows[0];
        assert_eq!((a.app.as_str(), a.nodes), ("a", 1));
        assert_eq!(a.verdict, "faster");
        assert!(a.speedup > 1.2 && a.speedup < 1.3, "{}", a.speedup);
        assert!(a.interval.as_ref().unwrap().entirely_below(0.0));
        let b = &report.rows[1];
        assert_eq!(b.verdict, "indistinguishable");
        let g = report.geomean_speedup().unwrap();
        assert!(g > 1.0 && g < a.speedup, "{g}");
        assert!(report.table().render().contains("faster"));
    }

    #[test]
    fn swapping_sides_inverts_the_verdicts() {
        let fwd = compare(&fixture(), Engine::Machine, "base", "cand", 0.95, 1);
        let rev = compare(&fixture(), Engine::Machine, "cand", "base", 0.95, 1);
        assert_eq!(fwd.rows.len(), rev.rows.len());
        for (f, r) in fwd.rows.iter().zip(&rev.rows) {
            let inverted = match f.verdict {
                "faster" => "slower",
                "slower" => "faster",
                v => v,
            };
            assert_eq!(r.verdict, inverted, "{}", f.app);
            assert!((f.speedup * r.speedup - 1.0).abs() < 1e-12);
        }
        assert_eq!(rev.only_candidate, 1); // `c` flips sides
    }

    #[test]
    fn shard_count_does_not_change_the_report() {
        let seq = compare(&fixture(), Engine::Machine, "base", "cand", 0.95, 1);
        for shards in [2, 4, 64] {
            let par = compare(&fixture(), Engine::Machine, "base", "cand", 0.95, shards);
            assert_eq!(seq.table().render(), par.table().render(), "shards={shards}");
        }
    }

    #[test]
    fn single_samples_are_insufficient_not_wrong() {
        let rows = vec![
            synthetic_row("a", "base", "runtime", 1, 0, "c0", 10.0),
            synthetic_row("a", "cand", "runtime", 1, 0, "c0", 5.0),
        ];
        let report = compare(&rows, Engine::Machine, "base", "cand", 0.95, 1);
        assert_eq!(report.rows[0].verdict, "insufficient");
        assert!((report.rows[0].speedup - 2.0).abs() < 1e-12);
    }
}
