//! Rank aggregation across engines (`exacb rank`, DESIGN.md §12).
//!
//! The rebar-style answer to "which machine wins overall?": every
//! (workload, metric, nodes) group ranks its engines by mean metric
//! value (lower is better, competition ranking — ties share a rank),
//! then the per-group ranks flatten into an aggregate per engine: mean
//! rank, win count, and the geometric mean of each engine's
//! ratio-to-best. Aggregating ratios instead of raw means keeps
//! incomparable workloads (seconds vs joules, 10 s apps vs 10 000 s
//! apps) from drowning each other out.

use super::{base_app, group_values, Engine};
use crate::store::Row;
use crate::util::table::Table;
use std::collections::BTreeMap;

/// One engine's standing inside a single (workload, metric, nodes)
/// group.
#[derive(Debug, Clone, PartialEq)]
pub struct RankedEngine {
    pub engine: String,
    /// Samples behind the mean.
    pub n: usize,
    pub mean: f64,
    /// Competition rank (1 = best; ties share the smaller rank).
    pub rank: usize,
    /// `mean / best_mean` in this group (1.0 for the winner).
    pub ratio_to_best: f64,
}

/// One fully-ranked (workload, metric, nodes) group.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadRanking {
    pub app: String,
    pub metric: String,
    pub nodes: u64,
    /// Engines in rank order (ties in mean broken by engine label).
    pub entries: Vec<RankedEngine>,
}

/// The aggregate standing of one engine across all groups it appears
/// in.
#[derive(Debug, Clone, PartialEq)]
pub struct AggregateRank {
    pub engine: String,
    /// Groups this engine was ranked in.
    pub groups: usize,
    /// Groups it won (rank 1, including shared wins).
    pub wins: usize,
    pub mean_rank: f64,
    /// Geometric mean of its per-group ratio-to-best.
    pub geomean_ratio: f64,
}

/// Per-group rankings plus the flattened aggregate.
#[derive(Debug, Clone)]
pub struct RankReport {
    pub engine: Engine,
    pub groups: Vec<WorkloadRanking>,
    /// Aggregates sorted best-first by (mean rank, geomean ratio,
    /// engine label).
    pub aggregate: Vec<AggregateRank>,
}

impl RankReport {
    /// Render the flattened aggregate as a table, best engine first.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "engine", "groups", "wins", "mean_rank", "geomean_ratio_to_best",
        ]);
        if self.aggregate.is_empty() {
            t.push_placeholder("(no ranked groups)");
            return t;
        }
        for a in &self.aggregate {
            t.push_row(vec![
                a.engine.clone(),
                a.groups.to_string(),
                a.wins.to_string(),
                format!("{:.3}", a.mean_rank),
                format!("{:.4}", a.geomean_ratio),
            ]);
        }
        t
    }

    /// Render every per-group ranking as one long table.
    pub fn groups_table(&self) -> Table {
        let mut t = Table::new(&[
            "workload", "metric", "nodes", "engine", "rank", "n", "mean", "ratio_to_best",
        ]);
        if self.groups.is_empty() {
            t.push_placeholder("(no ranked groups)");
            return t;
        }
        for g in &self.groups {
            for e in &g.entries {
                t.push_row(vec![
                    g.app.clone(),
                    g.metric.clone(),
                    g.nodes.to_string(),
                    e.engine.clone(),
                    e.rank.to_string(),
                    e.n.to_string(),
                    format!("{:.4}", e.mean),
                    format!("{:.4}", e.ratio_to_best),
                ]);
            }
        }
        t
    }
}

/// Rank every engine along the `engine` axis over a canonical row set.
/// Groups with a single engine are dropped (a walkover is not a win).
/// `shards` bounds the grouping fan-out; the report is identical for
/// any shard count (property-tested).
pub fn rank(rows: &[Row], engine: Engine, shards: usize) -> RankReport {
    let grouped = group_values(rows, shards, |r| {
        let app = match engine {
            Engine::Machine => base_app(&r.app, &r.machine).to_string(),
            Engine::Commit => r.app.clone(),
        };
        Some(((app, r.metric.clone(), r.nodes), engine.of(r).to_string()))
    });
    // (workload key → engine → values); BTreeMap iteration keeps both
    // levels deterministically ordered
    let mut by_group: BTreeMap<(String, String, u64), BTreeMap<String, Vec<f64>>> =
        BTreeMap::new();
    for ((key, eng), vs) in grouped {
        by_group.entry(key).or_default().insert(eng, vs);
    }
    let mut groups = Vec::new();
    let mut agg: BTreeMap<String, (usize, usize, usize, f64)> = BTreeMap::new();
    for ((app, metric, nodes), engines) in by_group {
        if engines.len() < 2 {
            continue;
        }
        let mut ranked: Vec<RankedEngine> = engines
            .into_iter()
            .map(|(engine, vs)| RankedEngine {
                engine,
                n: vs.len(),
                mean: vs.iter().sum::<f64>() / vs.len() as f64,
                rank: 0,
                ratio_to_best: 0.0,
            })
            .collect();
        ranked.sort_by(|a, b| {
            a.mean
                .partial_cmp(&b.mean)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.engine.cmp(&b.engine))
        });
        let best = ranked[0].mean;
        for i in 0..ranked.len() {
            // competition ranking: a tie shares the earlier rank
            let rank = if i > 0 && ranked[i].mean == ranked[i - 1].mean {
                ranked[i - 1].rank
            } else {
                i + 1
            };
            ranked[i].rank = rank;
            ranked[i].ratio_to_best = if best > 0.0 {
                ranked[i].mean / best
            } else {
                1.0
            };
        }
        for e in &ranked {
            let slot = agg.entry(e.engine.clone()).or_insert((0, 0, 0, 0.0));
            slot.0 += 1;
            if e.rank == 1 {
                slot.1 += 1;
            }
            slot.2 += e.rank;
            slot.3 += e.ratio_to_best.max(f64::MIN_POSITIVE).ln();
        }
        groups.push(WorkloadRanking { app, metric, nodes, entries: ranked });
    }
    let mut aggregate: Vec<AggregateRank> = agg
        .into_iter()
        .map(|(engine, (groups, wins, rank_sum, ln_sum))| AggregateRank {
            engine,
            groups,
            wins,
            mean_rank: rank_sum as f64 / groups as f64,
            geomean_ratio: (ln_sum / groups as f64).exp(),
        })
        .collect();
    aggregate.sort_by(|a, b| {
        a.mean_rank
            .partial_cmp(&b.mean_rank)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| {
                a.geomean_ratio
                    .partial_cmp(&b.geomean_ratio)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .then_with(|| a.engine.cmp(&b.engine))
    });
    RankReport { engine, groups, aggregate }
}

#[cfg(test)]
mod tests {
    use super::super::synthetic_row;
    use super::*;

    /// Three machines over two workloads: `fast` wins both, `mid` and
    /// `slow` split second place; workload `solo` has one engine only.
    fn fixture() -> Vec<Row> {
        let mut rows = Vec::new();
        for i in 0..4i64 {
            for (machine, a_val, b_val) in
                [("fast", 1.0, 2.0), ("mid", 2.0, 6.0), ("slow", 4.0, 4.0)]
            {
                rows.push(synthetic_row("a", machine, "runtime", 1, i, "c0", a_val));
                rows.push(synthetic_row("b", machine, "runtime", 1, i, "c0", b_val));
            }
            rows.push(synthetic_row("solo", "fast", "runtime", 1, i, "c0", 1.0));
        }
        rows
    }

    #[test]
    fn ranks_engines_and_flattens() {
        let report = rank(&fixture(), Engine::Machine, 1);
        assert_eq!(report.groups.len(), 2, "walkover group must be dropped");
        let a = &report.groups[0];
        assert_eq!(a.app, "a");
        assert_eq!(
            a.entries.iter().map(|e| e.engine.as_str()).collect::<Vec<_>>(),
            vec!["fast", "mid", "slow"]
        );
        assert_eq!(a.entries[2].rank, 3);
        assert!((a.entries[2].ratio_to_best - 4.0).abs() < 1e-12);
        let agg = &report.aggregate;
        assert_eq!(agg[0].engine, "fast");
        assert_eq!(agg[0].wins, 2);
        assert!((agg[0].mean_rank - 1.0).abs() < 1e-12);
        assert!((agg[0].geomean_ratio - 1.0).abs() < 1e-12);
        // mid: ranks 2 and 3 → 2.5; slow: ranks 3 and 2 → 2.5; the
        // geomean ratio breaks the tie in mid's favour (2·3 < 4·2)
        assert_eq!(agg[1].engine, "mid");
        assert_eq!(agg[2].engine, "slow");
        assert!((agg[1].mean_rank - 2.5).abs() < 1e-12);
        assert!((agg[2].mean_rank - 2.5).abs() < 1e-12);
        assert!(agg[1].geomean_ratio < agg[2].geomean_ratio);
        assert!(report.table().render().contains("fast"));
        assert!(report.groups_table().render().contains("ratio_to_best"));
    }

    #[test]
    fn ties_share_the_earlier_rank() {
        let rows = vec![
            synthetic_row("a", "x", "runtime", 1, 0, "c0", 3.0),
            synthetic_row("a", "y", "runtime", 1, 0, "c0", 3.0),
            synthetic_row("a", "z", "runtime", 1, 0, "c0", 5.0),
        ];
        let report = rank(&rows, Engine::Machine, 1);
        let ranks: Vec<(String, usize)> = report.groups[0]
            .entries
            .iter()
            .map(|e| (e.engine.clone(), e.rank))
            .collect();
        assert_eq!(
            ranks,
            vec![("x".to_string(), 1), ("y".to_string(), 1), ("z".to_string(), 3)]
        );
        // both tied winners count as wins
        assert_eq!(report.aggregate.iter().filter(|a| a.wins == 1).count(), 2);
    }

    #[test]
    fn relabeling_engines_permutes_but_preserves_standings() {
        // antisymmetry under label swap: swapping two machines' labels
        // must swap their aggregate rows and change nothing else
        let rows = fixture();
        let swapped: Vec<Row> = rows
            .iter()
            .map(|r| {
                let workload = super::super::base_app(&r.app, &r.machine).to_string();
                let mut r = r.clone();
                r.machine = match r.machine.as_str() {
                    "fast" => "slow".to_string(),
                    "slow" => "fast".to_string(),
                    m => m.to_string(),
                };
                // keep the store prefix coherent with the new label
                r.app = format!("{}.{workload}", r.machine);
                r
            })
            .collect();
        let orig = rank(&rows, Engine::Machine, 1);
        let swap = rank(&swapped, Engine::Machine, 1);
        let find = |rep: &RankReport, e: &str| {
            rep.aggregate.iter().find(|a| a.engine == e).cloned().unwrap()
        };
        let f_orig = find(&orig, "fast");
        let s_swap = find(&swap, "slow");
        assert_eq!(f_orig.mean_rank, s_swap.mean_rank);
        assert_eq!(f_orig.wins, s_swap.wins);
        assert_eq!(f_orig.geomean_ratio, s_swap.geomean_ratio);
        let m_orig = find(&orig, "mid");
        let m_swap = find(&swap, "mid");
        assert_eq!(m_orig.mean_rank, m_swap.mean_rank);
    }

    #[test]
    fn shard_count_does_not_change_the_report() {
        let seq = rank(&fixture(), Engine::Machine, 1);
        for shards in [2, 5, 32] {
            let par = rank(&fixture(), Engine::Machine, shards);
            assert_eq!(seq.groups, par.groups, "shards={shards}");
            assert_eq!(seq.aggregate, par.aggregate, "shards={shards}");
        }
    }
}
