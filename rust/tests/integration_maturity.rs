//! End-to-end tests of the maturity subsystem (DESIGN.md §10): the
//! evidence-based ladder's assessment properties, the promotion gate,
//! and the onboarding campaign's exact transition days.

use exacb::ci::{CiJobState, Trigger};
use exacb::coordinator::{BenchmarkRepo, World};
use exacb::maturity::{assess_repo, earned_level, Assessment, CriteriaConfig};
use exacb::prop_assert;
use exacb::util::json::Json;
use exacb::util::prop::check;
use exacb::util::timeutil::SimTime;
use exacb::workloads::onboarding::OnboardingScenario;
use exacb::workloads::portfolio::Maturity;

/// Build one synthetic recorded (report, csv) pair.
fn report(
    system: &str,
    day: i64,
    pipeline: u64,
    seed: u64,
    stage: &str,
    success: bool,
    instrumented: bool,
) -> (String, String) {
    use exacb::protocol::{results_csv, DataEntry, Experiment, Report, Reporter};
    let mut metrics = Json::obj().set("gflops_rate", 11.5);
    if instrumented {
        metrics.insert("kernel_time", 0.25 + day as f64);
    }
    let r = Report {
        reporter: Reporter {
            tool: "exacb".into(),
            tool_version: "0.1".into(),
            pipeline_id: pipeline,
            commit: format!("c{pipeline}"),
            system: system.into(),
            timestamp: SimTime::from_days(day).iso8601(),
            seed,
            ..Default::default()
        },
        parameter: Json::obj(),
        experiment: Experiment {
            system: system.into(),
            software_version: stage.into(),
            timestamp: SimTime::from_days(day).add_secs(3 * 3600).iso8601(),
            ..Default::default()
        },
        data: vec![DataEntry {
            success,
            runtime: 7.5 + day as f64,
            nodes: 1,
            metrics,
            ..Default::default()
        }],
    };
    let csv = results_csv(&[&r]);
    (r.to_document(), csv)
}

/// Property: assessment is **order-independent** — any permutation of
/// the same recorded documents reconstructs the identical evidence and
/// earned level.
#[test]
fn assessment_is_ingestion_order_independent() {
    let cfg = CriteriaConfig::default();
    check("maturity assessment independent of ingestion order", 40, |g| {
        let n = g.usize(1, 10);
        let docs: Vec<(String, String, String)> = (0..n)
            .map(|i| {
                let (doc, csv) = report(
                    if g.bool() { "jupiter" } else { "jedi" },
                    g.i64(0, 6),
                    g.u64(1, 40),
                    g.u64(0, 2),
                    if g.bool() { "stage-2026" } else { "" },
                    g.bool(),
                    g.bool(),
                );
                // occasionally alias two entries to the same path suffix
                // so replay footprints appear in both orders
                (format!("p/{}/report.json", g.usize(0, n)), doc, csv)
            })
            .map(|(p, d, c)| (p, d, c))
            .collect();
        let mut forward = Assessment::new(&cfg);
        for (p, d, c) in &docs {
            forward.ingest(p, d, Some(c));
        }
        let mut shuffled = docs.clone();
        for i in (1..shuffled.len()).rev() {
            let j = g.usize(0, i);
            shuffled.swap(i, j);
        }
        let mut backward = Assessment::new(&cfg);
        for (p, d, c) in &shuffled {
            backward.ingest(p, d, Some(c));
        }
        let (a, b) = (forward.evidence(None), backward.evidence(None));
        prop_assert!(a == b, "evidence diverges:\n  {a:?}\n  {b:?}");
        prop_assert!(
            earned_level(&a, &cfg) == earned_level(&b, &cfg),
            "earned level diverges"
        );
        Ok(())
    });
}

/// Property: promotion is **monotone in evidence** — ingesting one more
/// recorded document never lowers the earned level.
#[test]
fn promotion_is_monotone_in_evidence() {
    let cfg = CriteriaConfig::default();
    check("earned level is monotone under added evidence", 40, |g| {
        let mut a = Assessment::new(&cfg);
        let mut last: Option<Maturity> = None;
        for i in 0..g.usize(3, 14) {
            let (doc, csv) = report(
                if g.bool() { "jupiter" } else { "jedi" },
                g.i64(0, 6),
                i as u64 + 1,
                g.u64(0, 2),
                if g.bool() { "stage-2026" } else { "" },
                g.bool(),
                g.bool(),
            );
            // replays (same doc at a second path) are also "more
            // evidence" and must never demote
            let path = format!("p/{}/report.json", g.usize(0, 9));
            a.ingest(&path, &doc, Some(&csv));
            let now = earned_level(&a.evidence(None), &cfg);
            prop_assert!(
                now >= last,
                "evidence demoted the level: {last:?} -> {now:?} after {} docs",
                i + 1
            );
            last = now;
        }
        Ok(())
    });
}

/// Warm cache replays never change the assessed maturity state: the
/// replayed bytes dedupe out of every counter, and once the replay
/// footprint exists, further replays are idempotent.
#[test]
fn warm_replays_never_change_assessed_state() {
    let cfg = CriteriaConfig::default();
    // three cold measurement days (no cache): distinct evidence points
    let mut world = World::new(42);
    world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
    for d in 0..3 {
        world.advance_to(SimTime::from_days(d).add_secs(3 * 3600));
        world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
    }
    let cold = assess_repo(world.repo("logmap").unwrap(), &cfg);
    assert_eq!(cold.evidence.successful_runs, 3);
    assert_eq!(cold.evidence.replay_commits, 0);
    assert_eq!(cold.earned, Some(Maturity::Instrumentability));

    // enable caching: the first cached run is a miss (a fourth distinct
    // evidence point), every later one a byte-identical replay
    world.enable_cache();
    world.advance_to(SimTime::from_days(3).add_secs(3 * 3600));
    world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
    let seeded = assess_repo(world.repo("logmap").unwrap(), &cfg);
    assert_eq!(seeded.evidence.successful_runs, 4);
    assert_eq!(seeded.evidence.replay_commits, 0);

    // warm replay: re-commits the day-3 report byte-identically at a
    // new path. The one and only thing that may change is the
    // replay-verified criterion — which this replay *earns*, promoting
    // to the top rung. No other counter moves.
    world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
    assert!(world.cache_stats().hits >= 1, "second cached run must replay");
    let warm = assess_repo(world.repo("logmap").unwrap(), &cfg);
    assert_eq!(warm.evidence.successful_runs, seeded.evidence.successful_runs);
    assert_eq!(
        warm.evidence.instrumented_runs,
        seeded.evidence.instrumented_runs
    );
    assert_eq!(warm.evidence.csv_ok, seeded.evidence.csv_ok);
    assert_eq!(warm.evidence.seeded_runs, seeded.evidence.seeded_runs);
    assert_eq!(warm.evidence.replay_commits, 1);
    assert_eq!(warm.earned, Some(Maturity::Reproducibility));

    // …and from here on, warm replays change nothing at all
    for _ in 0..4 {
        world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
    }
    let again = assess_repo(world.repo("logmap").unwrap(), &cfg);
    assert_eq!(again.evidence, warm.evidence, "replays are evidence of nothing new");
    assert_eq!(again.earned, warm.earned);
}

/// The gate denies promotion on missing evidence, naming every unmet
/// criterion and its shortfall in `maturity.json`.
#[test]
fn gate_denies_with_named_criteria() {
    let mut world = World::new(9);
    let mut repo = BenchmarkRepo::logmap_example("jedi", "all");
    // one single successful run: runnable evidence exists but is thin
    world.add_repo(repo.clone());
    world.run_pipeline("logmap", Trigger::Manual).unwrap();
    repo = world.repos.remove("logmap").unwrap();

    let inputs = Json::obj()
        .set("prefix", "jedi.logmap")
        .set("target", "reproducibility")
        .set("min_runs", 3u64);
    let jobs = exacb::maturity::run_maturity_gate(&mut world, &mut repo, &inputs, 99);
    let gate = jobs.last().unwrap();
    assert_eq!(gate.state, CiJobState::Failed, "promotion must be denied");
    let doc = Json::parse(gate.artifact("maturity.json").unwrap()).unwrap();
    assert_eq!(doc.str_of("verdict"), Some("denied"));
    assert_eq!(doc.str_of("target"), Some("reproducibility"));
    let unmet = doc.get("unmet").and_then(Json::as_arr).unwrap();
    let names: Vec<&str> = unmet
        .iter()
        .filter_map(|u| u.str_of("criterion"))
        .collect();
    assert!(names.contains(&"successful-runs"), "{names:?}");
    assert!(names.contains(&"replay-verified"), "{names:?}");
    for u in unmet {
        assert!(u.str_of("missing").is_some(), "shortfall text present");
    }
    // denial never touches the declared level
    assert_eq!(repo.maturity, Maturity::Reproducibility);
}

/// A *target* gate only blocks or grants — granting a rung below the
/// declared level must never silently demote the repository (demotion
/// is assess mode's job, with its recency window).
#[test]
fn granting_a_lower_target_never_demotes() {
    let mut world = World::new(11);
    world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
    for d in 0..3 {
        world.advance_to(SimTime::from_days(d).add_secs(3 * 3600));
        world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
    }
    let mut repo = world.repos.remove("logmap").unwrap();
    assert_eq!(repo.maturity, Maturity::Reproducibility); // declared
    let inputs = Json::obj()
        .set("prefix", "jedi.logmap")
        .set("target", "runnability");
    let jobs = exacb::maturity::run_maturity_gate(&mut world, &mut repo, &inputs, 77);
    let gate = jobs.last().unwrap();
    assert_eq!(gate.state, CiJobState::Success);
    let doc = Json::parse(gate.artifact("maturity.json").unwrap()).unwrap();
    assert_eq!(doc.str_of("verdict"), Some("granted"));
    // earned is instrumentability (no replay proof), target was met,
    // and the declared top rung survives the grant
    assert_eq!(doc.str_of("earned"), Some("instrumentability"));
    assert_eq!(doc.str_of("level"), Some("reproducibility"));
    assert_eq!(repo.maturity, Maturity::Reproducibility);
}

/// The maturity sidecar stays out of recorded history: no report on the
/// data branch ever embeds a gate verdict.
#[test]
fn maturity_sidecar_never_leaks_into_reports() {
    let sc = OnboardingScenario::generate(3, 5, 77);
    let mut world = World::new(sc.seed);
    exacb::maturity::run_onboarding(&mut world, &sc);
    let mut reports_seen = 0;
    for oa in &sc.apps {
        let repo = world.repo(&oa.app.name).unwrap();
        for (path, content) in repo.store.read_all("exacb.data", "") {
            if !path.ends_with("report.json") {
                continue;
            }
            reports_seen += 1;
            exacb::protocol::Report::parse(&content)
                .unwrap_or_else(|e| panic!("{path}: {e}"));
            assert!(
                !content.contains("maturity.json") && !content.contains("\"verdict\""),
                "{path} must not embed gate output"
            );
        }
    }
    assert!(reports_seen >= 3 * 5, "campaign recorded {reports_seen} reports");
}

/// Planted onboarding events land on their exact expected days:
/// instrumentation earns instrumentability, the replay audit earns
/// reproducibility, breakage demotes when windowed evidence decays, and
/// the fix re-earns the level — all through full pipelines on the
/// shared timeline.
#[test]
fn onboarding_transitions_land_on_exact_days() {
    use exacb::workloads::onboarding::OnboardingApp;
    use exacb::workloads::portfolio::PortfolioApp;
    use exacb::workloads::scalable::AppModel;

    let app = |name: &str, declared: Maturity| OnboardingApp {
        app: PortfolioApp {
            name: name.to_string(),
            domain: "cfd".to_string(),
            maturity: declared,
            model: AppModel {
                name: name.to_string(),
                gflops_total: 20_000.0,
                steps: 10,
                ..AppModel::default()
            },
            failure_rate: 0.0,
            nodes: 1,
        },
        declared,
        instrument_from: None,
        verify_from: None,
        break_day: None,
        fix_day: None,
    };
    let mut late_bloomer = app("late-bloomer", Maturity::Runnability);
    late_bloomer.instrument_from = Some(6);
    let mut auditee = app("auditee", Maturity::Instrumentability);
    auditee.instrument_from = Some(0);
    auditee.verify_from = Some(5);
    let mut flaky = app("flaky", Maturity::Instrumentability);
    flaky.instrument_from = Some(0);
    flaky.break_day = Some(5);
    flaky.fix_day = Some(9);
    let sc = OnboardingScenario {
        apps: vec![late_bloomer, auditee, flaky],
        days: 13,
        machines: vec!["jupiter".to_string()],
        queue: "all".to_string(),
        seed: 314,
        verify_every: 4,
        min_runs: 3,
        min_instrumented: 3,
        window_days: 6,
    };
    let mut world = World::new(sc.seed);
    let out = exacb::maturity::run_onboarding(&mut world, &sc);

    // late-bloomer: instrumented from day 6 → 3 instrumented runs on
    // day 8, exactly
    assert_eq!(sc.expected_instrumentability_day(0), Some(8));
    assert_eq!(
        out.transition_day("late-bloomer", Maturity::Instrumentability),
        Some(8),
        "{:?}",
        out.transitions_of("late-bloomer")
    );

    // auditee: opts into the replay audit on day 5 → proven on the
    // day-7 audit, exactly
    assert_eq!(sc.expected_reproducibility_day(1), Some(7));
    assert_eq!(
        out.transition_day("auditee", Maturity::Reproducibility),
        Some(7),
        "{:?}",
        out.transitions_of("auditee")
    );

    // flaky: breaks on day 5 → windowed successes drop below min_runs
    // on day 5+6-3=8, demoting to the floor; fixed on day 9 → re-earns
    // instrumentability on day 9+3-1=11, exactly
    assert_eq!(sc.expected_demotion_day(2), Some(8));
    assert_eq!(sc.expected_repromotion_day(2), Some(11));
    let flaky_t = out.transitions_of("flaky");
    assert_eq!(
        out.transition_day("flaky", Maturity::Runnability),
        Some(8),
        "{flaky_t:?}"
    );
    let reearn = flaky_t
        .iter()
        .find(|t| t.day > 8 && t.to == Maturity::Instrumentability)
        .unwrap_or_else(|| panic!("no re-promotion: {flaky_t:?}"));
    assert_eq!(reearn.day, 11);
}
