//! BYOB definition layer, end to end (DESIGN.md §15).
//!
//! The keystone property: the shipped `benchmarks/` directory is the
//! built-in JUREAP portfolio **as data**, and running it through
//! `exacb measure`'s core (`defs::run_measure_with`) replays the code
//! path byte-identically — same `sacct` records, same recorded stores,
//! same queue statistics and results tables — cold and warm, under both
//! the indexed event loop and the frozen reference scan. Three paths
//! are compared per driver:
//!
//! 1. **code** — `portfolio::jureap()` + `World::new` + the campaign
//!    core, the way every pre-BYOB caller runs it;
//! 2. **builtin defs** — `defs::builtin()` through `run_measure_with`;
//! 3. **shipped** — `defs::load_dir("benchmarks/")` through the same.
//!
//! Any divergence means definitions are *not* just data (a conversion
//! bug, a float that didn't round-trip, machine state leaking), which
//! is exactly the regression this suite exists to catch.

use exacb::coordinator::{collection, event_loop, postproc, World};
use exacb::defs::{self, MeasurePlan};
use exacb::util::prng::Prng;
use exacb::util::tomlite;
use exacb::workloads::portfolio;

fn shipped_dir() -> String {
    format!("{}/../benchmarks", env!("CARGO_MANIFEST_DIR"))
}

const APPS: usize = 24;
const DAYS: i64 = 2;
const SWEEPS: u32 = 2; // sweep 1 cold, sweep 2 warm (cache replay)
const MACHINES: [&str; 3] = ["jedi", "jupiter", "jureca"];
const SEED: u64 = 20260101;

/// Every `sacct` field of every job on every machine, in jobid order.
fn sacct_dump(world: &World) -> String {
    let mut out = String::new();
    for (name, bs) in &world.batch {
        for r in bs.records_iter() {
            out.push_str(&format!(
                "{name} {} {} {:?} {:?} {:?} {} {} {:?}\n",
                r.jobid,
                r.state.name(),
                r.submit_time,
                r.start_time,
                r.end_time,
                r.spec.partition,
                r.spec.nodes,
                r.result
                    .as_ref()
                    .map(|res| (res.success, res.duration_s)),
            ));
        }
    }
    out
}

/// Every file on every branch of every repository store.
fn store_dump(world: &World) -> String {
    let mut out = String::new();
    for (name, repo) in &world.repos {
        let mut branches = repo.store.branches();
        branches.sort_unstable();
        for branch in branches {
            for (path, content) in repo.store.read_all(branch, "") {
                out.push_str(&format!("{name} {branch} {path} {}\n", content.len()));
                out.push_str(&content);
                out.push('\n');
            }
        }
    }
    out
}

/// The full observable outcome of a campaign, as comparable strings.
struct Outcome {
    sacct: String,
    stores: String,
    queue_stats: String,
    results: Vec<String>,
    summaries: String,
}

fn outcome(world: &World, summaries: &[collection::CollectionSummary]) -> Outcome {
    Outcome {
        sacct: sacct_dump(world),
        stores: store_dump(world),
        queue_stats: postproc::queue_stats(world).to_csv(),
        results: ["runtime", "tts"]
            .iter()
            .map(|m| postproc::collection_results_table(world, m).to_csv())
            .collect(),
        summaries: format!("{summaries:?}"),
    }
}

/// Path 1: the pre-BYOB code path, replicating `run_measure_with`'s
/// loop by hand over the built-in constructors.
fn campaign_via_code(
    drive: fn(&mut World, Vec<event_loop::PipelineTask>) -> Vec<u64>,
) -> Outcome {
    let mut apps = portfolio::jureap();
    apps.truncate(APPS);
    let mut world = World::new(SEED);
    world.enable_cache();
    collection::onboard_multi(&mut world, &apps, &MACHINES, "all");
    let mut summaries = Vec::new();
    for _ in 0..SWEEPS {
        summaries.push(collection::run_campaign_concurrent_with(
            &mut world, &apps, &MACHINES, DAYS, drive,
        ));
    }
    outcome(&world, &summaries)
}

fn measure_plan() -> MeasurePlan {
    MeasurePlan {
        apps: APPS,
        days: DAYS,
        machines: MACHINES.iter().map(|m| m.to_string()).collect(),
        queue: "all".to_string(),
        seed: SEED,
        cache: true,
        sweeps: SWEEPS,
    }
}

/// Paths 2 and 3: a definition set through the `exacb measure` core.
fn campaign_via_defs(
    set: &defs::DefSet,
    drive: fn(&mut World, Vec<event_loop::PipelineTask>) -> Vec<u64>,
) -> Outcome {
    let (world, summaries) =
        defs::run_measure_with(set, &measure_plan(), drive).expect("measure plan must run");
    outcome(&world, &summaries)
}

fn assert_same(label: &str, a: &Outcome, b: &Outcome) {
    assert_eq!(a.summaries, b.summaries, "{label}: campaign summaries diverged");
    assert_eq!(a.queue_stats, b.queue_stats, "{label}: queue stats diverged");
    assert_eq!(a.results, b.results, "{label}: results tables diverged");
    assert_eq!(a.sacct, b.sacct, "{label}: sacct records diverged");
    assert_eq!(a.stores, b.stores, "{label}: recorded stores diverged");
}

/// The shipped `benchmarks/` directory parses to exactly the built-in
/// definition set — every f64 bit-identical (the generator and the
/// loud-parse round trip are both on trial here).
#[test]
fn shipped_benchmarks_equal_builtin_bit_for_bit() {
    let shipped = defs::load_dir(&shipped_dir()).expect("shipped benchmarks/ must load clean");
    let builtin = defs::builtin();
    assert_eq!(shipped.apps.len(), 72);
    assert_eq!(shipped.machines.len(), 4);
    assert_eq!(shipped.engines.len(), 1);
    // DefSet equality ignores file provenance but compares every f64 by
    // bits (non-NaN ==), every name, every partition list, in order.
    assert_eq!(shipped, builtin);
}

/// The differential property, indexed event loop: code path, built-in
/// defs, and the shipped directory replay the same campaign
/// byte-identically, cold sweep and warm (cached) sweep alike.
#[test]
fn shipped_defs_replay_code_path_byte_identical_under_drive() {
    let code = campaign_via_code(event_loop::drive);
    let via_builtin = campaign_via_defs(&defs::builtin(), event_loop::drive);
    let shipped = defs::load_dir(&shipped_dir()).unwrap();
    let via_shipped = campaign_via_defs(&shipped, event_loop::drive);
    assert_same("builtin defs vs code", &via_builtin, &code);
    assert_same("shipped dir vs code", &via_shipped, &code);
    // the warm sweep must actually have replayed from cache, or the
    // "cold + warm" half of the claim is vacuous
    assert!(
        code.summaries.contains("hits"),
        "summary Debug lost cache stats: {}",
        code.summaries
    );
}

/// Same property under the frozen reference scan — proves the defs
/// layer is driver-agnostic (it only hands tasks to the loop).
#[test]
fn shipped_defs_replay_code_path_byte_identical_under_reference() {
    let code = campaign_via_code(event_loop::drive_reference);
    let via_shipped = campaign_via_defs(
        &defs::load_dir(&shipped_dir()).unwrap(),
        event_loop::drive_reference,
    );
    assert_same("shipped dir vs code (reference)", &via_shipped, &code);
}

/// The warm sweep replays from the execution cache. Summary cache
/// stats are cumulative world totals, so the warm sweep must add hits
/// and add no misses beyond the cold sweep's population.
#[test]
fn warm_sweep_hits_the_execution_cache() {
    let shipped = defs::load_dir(&shipped_dir()).unwrap();
    let (_, summaries) =
        defs::run_measure_with(&shipped, &measure_plan(), event_loop::drive).unwrap();
    assert_eq!(summaries.len(), SWEEPS as usize);
    let (cold, warm) = (&summaries[0].cache, &summaries[1].cache);
    assert!(cold.misses > 0, "cold sweep must populate the cache");
    assert!(
        warm.hits > cold.hits,
        "warm sweep must replay from cache: cold {cold:?} warm {warm:?}"
    );
    assert_eq!(
        warm.misses, cold.misses,
        "warm sweep over unchanged inputs must not miss"
    );
}

/// Property: tomlite round-trips seeded f64s bit-exactly through the
/// `{v:?}` rendering `defs::render` uses — including subnormal-adjacent
/// tiny values and exponent forms the portfolio can produce.
#[test]
fn prop_tomlite_round_trips_rendered_floats_bit_exact() {
    let mut rng = Prng::new(0xBEEF);
    let mut values: Vec<f64> = vec![0.0, 1.0, 0.1, 8.7e-5, 1e-12, 5e15, 0.010, 499999.9999999999];
    for _ in 0..500 {
        values.push(rng.range_f64(0.0, 1.0));
        values.push(rng.range_f64(5_000.0, 500_000.0));
        values.push(rng.range_f64(0.0, 1e-3)); // exponent-form territory
    }
    for v in values {
        let doc = tomlite::parse(&format!("v = {v:?}\n")).expect("rendered float must parse");
        let back = doc.pointer("v").and_then(|j| j.as_f64()).expect("float key");
        assert_eq!(
            back.to_bits(),
            v.to_bits(),
            "{v:?} reparsed as {back:?}"
        );
    }
}

/// Property: every validation error names its file, table, and key, so
/// a CI lint failure on a 500-file directory is actionable. Seeded
/// corruptions of the rendered built-in set must each produce an error
/// mentioning the corrupted file and its `[[table]]`.
#[test]
fn prop_validation_errors_name_file_table_and_key() {
    let rendered = defs::render(&defs::builtin());
    // corrupt jureap.toml: negate every steps value -> one named error
    // per app, each pointing at the right file and table
    let corrupted: Vec<(String, String)> = rendered
        .iter()
        .map(|(name, text)| {
            let text = if name == "jureap.toml" {
                text.replace("steps = ", "steps = -")
            } else {
                text.clone()
            };
            (name.clone(), text)
        })
        .collect();
    let err = defs::parse_files(&corrupted).expect_err("negative steps must not validate");
    let msg = err.to_string();
    assert!(msg.contains("jureap.toml"), "no file name in: {msg}");
    assert!(msg.contains("[[app]]"), "no table in: {msg}");
    assert!(msg.contains("steps"), "no key in: {msg}");
    assert!(msg.contains("climate-01"), "table should name the app: {msg}");

    // corrupt machines.toml: break one machine's power fingerprint
    let corrupted: Vec<(String, String)> = rendered
        .iter()
        .map(|(name, text)| {
            let text = if name == "machines.toml" {
                text.replacen("tdp_w = 700.0", "tdp_w = 0.0", 1)
            } else {
                text.clone()
            };
            (name.clone(), text)
        })
        .collect();
    let err = defs::parse_files(&corrupted).expect_err("tdp <= idle must not validate");
    let msg = err.to_string();
    assert!(msg.contains("machines.toml"), "no file name in: {msg}");
    assert!(msg.contains("jedi"), "no machine name in: {msg}");
    assert!(msg.contains("tdp_w"), "no key in: {msg}");
}

/// Duplicate keys are load-time errors with line numbers in both
/// in-repo config dialects (satellite: yamlite and tomlite agree).
#[test]
fn duplicate_keys_rejected_with_line_numbers() {
    let err = tomlite::parse("a = 1\na = 2\n").expect_err("dup key");
    assert_eq!(err.line, 2, "{err}");
    assert!(err.to_string().contains("duplicate"), "{err}");

    let err = exacb::util::yamlite::parse("a: 1\na: 2\n").expect_err("dup key");
    assert!(err.to_string().contains("duplicate"), "{err}");
    assert!(err.to_string().contains("line 2"), "{err}");
}
