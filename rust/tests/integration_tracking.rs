//! End-to-end tests of the tracking subsystem (DESIGN.md §9): the
//! regression gate over planted-slowdown and unchanged scenarios, and
//! the digest-keyed history's replay immunity.

use exacb::ci::{CiJobState, Trigger};
use exacb::coordinator::{BenchmarkRepo, World};
use exacb::tracking::{self, History};
use exacb::util::json::Json;
use exacb::workloads::regression::RegressionScenario;

/// A pipeline on a branch with a planted >=10% slowdown must fail the
/// regression gate on the injection day — with a `regressions.json`
/// artifact naming the metric and the interval — and must never fail
/// before it.
#[test]
fn planted_regression_fails_the_gate_on_inject_day() {
    let sc = RegressionScenario::planted("jedi", 8, 5, 15.0, 314159);
    let mut world = World::new(sc.seed);
    let outcome = tracking::run_scenario(&mut world, &sc);

    assert!(
        outcome.failed_days.contains(&5),
        "inject day must fail; failed: {:?}, gates: {:?}",
        outcome.failed_days,
        outcome.gate_by_day
    );
    assert!(
        outcome.failed_days.iter().all(|d| *d >= 5),
        "no failure before the planted change: {:?}",
        outcome.failed_days
    );
    assert_eq!(outcome.verdict_on(5), Some("regression"));

    // the gate decided within the repetition budget
    let extra = outcome.extra_reps_on(5).unwrap();
    assert!(
        extra <= sc.max_extra_repetitions,
        "extra {extra} beyond budget {}",
        sc.max_extra_repetitions
    );

    // regressions.json names the metric and the interval
    let (_, pid, _) = outcome.pipelines[5];
    let pipeline = world.pipeline(pid).unwrap();
    let gate = pipeline
        .jobs
        .iter()
        .find(|j| j.name.ends_with(".regression-check"))
        .expect("gate job present");
    assert_eq!(gate.state, CiJobState::Failed);
    let doc = Json::parse(gate.artifact("regressions.json").unwrap()).unwrap();
    assert_eq!(doc.str_of("metric"), Some("runtime"));
    assert_eq!(doc.str_of("verdict"), Some("regression"));
    let series = doc.get("series").and_then(Json::as_arr).unwrap();
    assert!(!series.is_empty());
    let s0 = &series[0];
    assert_eq!(s0.str_of("verdict"), Some("regression"));
    let interval = s0.get("interval").unwrap();
    let lo_pct = interval.f64_of("lo_pct").unwrap();
    assert!(
        lo_pct > sc.threshold_pct as f64,
        "interval lower bound {lo_pct}% must clear the {}% threshold",
        sc.threshold_pct
    );
    // the sidecar stays out of report.json: no recorded report mentions it
    let repo = world.repo(&sc.app).unwrap();
    for (path, content) in repo.store.read_all("exacb.data", "") {
        assert!(
            !content.contains("regressions.json") && !content.contains("\"verdict\""),
            "{path} must not embed gate output"
        );
    }
}

/// An unchanged branch passes every day with zero extra repetitions
/// beyond the adaptive minimum (the gate tops the candidate sample up
/// to `min_repetitions` and then decides in one shot).
#[test]
fn unchanged_branch_stays_green_with_adaptive_minimum() {
    let sc = RegressionScenario::control("jedi", 8, 271828);
    let mut world = World::new(sc.seed);
    let outcome = tracking::run_scenario(&mut world, &sc);

    assert!(
        outcome.failed_days.is_empty(),
        "control must stay green: {:?} ({:?})",
        outcome.failed_days,
        outcome.gate_by_day
    );
    for (day, verdict, extra) in &outcome.gate_by_day {
        if verdict == "no-baseline" {
            assert_eq!(*extra, 0, "day {day}: no repetitions before the gate is armed");
        } else {
            assert_eq!(verdict, "stable", "day {day}");
            assert_eq!(
                *extra,
                sc.expected_min_extra(),
                "day {day}: exactly the adaptive minimum, no refinement rounds"
            );
        }
    }
    // both regimes actually occurred
    assert!(outcome.gate_by_day.iter().any(|(_, v, _)| v == "no-baseline"));
    assert!(outcome.gate_by_day.iter().any(|(_, v, _)| v == "stable"));
}

/// A cache-warm replayed run re-commits a byte-identical report under a
/// new store path; the digest-keyed history must not grow a new point.
#[test]
fn cache_warm_replay_never_creates_a_history_point() {
    let mut world = World::new(42);
    world.enable_cache();
    world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
    world.run_pipeline("logmap", Trigger::Manual).unwrap();

    let repo = world.repo("logmap").unwrap();
    let (h1, _) = History::from_store(&repo.store, "exacb.data", "", &["runtime"]);
    let cold_points = h1.total_points();
    let cold_paths = repo.store.list("exacb.data", "").len();
    assert!(cold_points > 0);

    // warm run: full cache replay, byte-identical report at a new path
    world.run_pipeline("logmap", Trigger::Manual).unwrap();
    assert!(world.cache_stats().hits >= 1, "second run must replay");
    let repo = world.repo("logmap").unwrap();
    assert!(
        repo.store.list("exacb.data", "").len() > cold_paths,
        "the replay does commit (provenance of the rerun)"
    );
    let (h2, _) = History::from_store(&repo.store, "exacb.data", "", &["runtime"]);
    assert_eq!(
        h2.total_points(),
        cold_points,
        "replayed bytes are evidence of nothing: no new history point"
    );
}

/// A *young* repository under a warm cache: the replayed execution
/// dedupes out of history, the baseline never reaches `min_baseline`,
/// and the gate must pass for free — zero repetitions, never a no-data
/// hard fail (DESIGN.md §9 rule 1 holds warm or cold).
#[test]
fn young_warm_gated_pipelines_pass_without_repetitions() {
    let sc = RegressionScenario::control("jedi", 3, 555);
    let mut world = World::new(sc.seed);
    world.enable_cache();
    let outcome = tracking::run_scenario(&mut world, &sc);
    assert!(
        outcome.failed_days.is_empty(),
        "young warm runs must stay green: {:?} ({:?})",
        outcome.failed_days,
        outcome.gate_by_day
    );
    assert!(world.cache_stats().hits >= 1, "executions must have replayed");
    for (day, verdict, extra) in &outcome.gate_by_day {
        assert_eq!(verdict, "no-baseline", "day {day}");
        assert_eq!(*extra, 0, "day {day}: an unarmed gate spends nothing");
    }
}

/// An *armed* gate under a warm cache: the replay contributes no
/// candidate point, so the gate measures exactly `min_repetitions`
/// fresh (cache-bypassing) runs and judges those — it neither
/// hard-fails with no-data nor trusts the replayed bytes.
#[test]
fn armed_warm_gated_pipeline_measures_fresh_repetitions() {
    use exacb::util::timeutil::SimTime;
    // arm the baseline with cold measurement days first
    let sc = RegressionScenario::control("jedi", 6, 556);
    let mut world = World::new(sc.seed);
    let outcome = tracking::run_scenario(&mut world, &sc);
    assert!(outcome.failed_days.is_empty(), "{:?}", outcome.gate_by_day);

    // first cached day: a cache miss that seeds the report-level entry
    world.enable_cache();
    world.advance_to(SimTime::from_days(6).add_secs(3 * 3600));
    let p1 = world.run_pipeline(&sc.app, Trigger::Scheduled).unwrap();
    assert!(world.pipeline(p1).unwrap().succeeded());

    // second cached day: the execution replays byte-identically and
    // dedupes out of history; the armed gate re-measures
    world.advance_to(SimTime::from_days(7).add_secs(3 * 3600));
    let p2 = world.run_pipeline(&sc.app, Trigger::Scheduled).unwrap();
    let p = world.pipeline(p2).unwrap();
    assert!(p.succeeded(), "warm gated pipeline must pass");
    assert!(world.cache_stats().hits >= 1, "day-7 execution must replay");
    let gate = p
        .jobs
        .iter()
        .find(|j| j.name.ends_with(".regression-check"))
        .unwrap();
    let doc = Json::parse(gate.artifact("regressions.json").unwrap()).unwrap();
    assert_eq!(doc.str_of("verdict"), Some("stable"));
    assert_eq!(doc.u64_of("extra_repetitions"), Some(sc.min_repetitions));
}

/// The gate component is schema-validated like every other component:
/// missing required execution inputs fail the pipeline's validation job
/// before anything runs.
#[test]
fn gate_inputs_are_schema_validated() {
    let mut world = World::new(9);
    let repo = BenchmarkRepo::new("misconfigured").with_file(
        ".gitlab-ci.yml",
        "component: regression-check@v1\ninputs:\n  prefix: p\n", // no machine/jube_file
    );
    world.add_repo(repo);
    let pid = world.run_pipeline("misconfigured", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(!p.succeeded());
    assert!(p.jobs[0].log[0].contains("input validation failed"), "{:?}", p.jobs[0].log);
}

/// Gate repetitions record under fresh pipeline ids on the same prefix:
/// every report on the data branch stays protocol-parseable and the
/// series keeps one benchmark identity.
#[test]
fn repetitions_record_parseable_reports_under_one_series() {
    let sc = RegressionScenario::control("jedi", 6, 1618);
    let mut world = World::new(sc.seed);
    tracking::run_scenario(&mut world, &sc);
    let repo = world.repo(&sc.app).unwrap();
    let mut reports = 0;
    for (path, content) in repo.store.read_all("exacb.data", "") {
        if path.ends_with("report.json") {
            exacb::protocol::Report::parse(&content)
                .unwrap_or_else(|e| panic!("{path}: {e}"));
            reports += 1;
        }
    }
    // 6 daily executions + min_repetitions-1 extra reps on each gated day
    assert!(reports >= 6 + 2 * (sc.min_repetitions as usize - 1), "got {reports}");
    let (hist, skipped) = History::from_store(&repo.store, "exacb.data", "", &["runtime"]);
    assert_eq!(skipped, 0);
    let series = hist.series();
    assert_eq!(series.len(), 1, "one (benchmark, system, metric, nodes) series");
    assert_eq!(series[0].key.benchmark, sc.prefix());
    assert_eq!(series[0].points.len(), reports);
    // per-commit provenance: the control never changes its commit
    let commits: std::collections::BTreeSet<_> =
        series[0].points.iter().map(|p| p.commit.clone()).collect();
    assert_eq!(commits.len(), 1);
}
