//! End-to-end tests for the energy subsystem (DESIGN.md §11): the
//! `energy-sweep@v1` component on the shared timeline, eligibility
//! filtering, the cache-stash contract, sidecar hygiene, and the
//! energy-metric path into the regression gate.

use exacb::ci::{CiJobState, Trigger};
use exacb::coordinator::{BenchmarkRepo, World};
use exacb::energy::study;
use exacb::util::json::Json;
use exacb::util::timeutil::SimTime;
use exacb::workloads::onboarding::{OnboardingApp, OnboardingScenario};
use exacb::workloads::portfolio::{Maturity, PortfolioApp};
use exacb::workloads::scalable::AppModel;

fn sweep_jube(name: &str, flops: u64) -> String {
    format!(
        "name: {name}\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: 1\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name {name} --flops {flops} --membound 0.5 --comm-mb 0 --steps 20\n"
    )
}

fn sweep_repo(concurrent: bool) -> BenchmarkRepo {
    let ci = format!(
        "include:\n  - component: energy-sweep@v1\n    inputs:\n      prefix: \"jedi.eapp\"\n      machine: \"jedi\"\n      queue: \"all\"\n      project: \"cjsc\"\n      budget: \"zam\"\n      jube_file: \"b.yml\"\n      points: 6\n      concurrent: \"{concurrent}\"\n"
    );
    BenchmarkRepo::new("eapp")
        .with_file("b.yml", &sweep_jube("eapp", 150_000))
        .with_file(".gitlab-ci.yml", &ci)
}

fn run_sweep_pipeline(concurrent: bool) -> (World, String, String) {
    let mut world = World::new(77);
    world.add_repo(sweep_repo(concurrent));
    let pid = world.run_pipeline("eapp", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(
        p.succeeded(),
        "jobs: {:?}",
        p.jobs.iter().map(|j| (&j.name, j.state)).collect::<Vec<_>>()
    );
    let analysis = p
        .jobs
        .iter()
        .find(|j| j.name.ends_with(".energy-analysis"))
        .expect("analysis job");
    let csv = analysis.artifact("energy.csv").unwrap().to_string();
    let sidecar = analysis.artifact("energy.json").unwrap().to_string();
    (world, csv, sidecar)
}

/// Drive one sweep through the public component entry point on a
/// byte-identical repository, toggling only the dispatch mode.
fn direct_sweep(concurrent: bool) -> (World, String, String) {
    let mut world = World::new(77);
    let mut repo = BenchmarkRepo::new("eapp").with_file("b.yml", &sweep_jube("eapp", 150_000));
    let inputs = Json::obj()
        .set("prefix", "jedi.eapp")
        .set("machine", "jedi")
        .set("queue", "all")
        .set("project", "cjsc")
        .set("budget", "zam")
        .set("jube_file", "b.yml")
        .set("points", 6u64)
        .set("concurrent", Json::Bool(concurrent));
    let jobs = study::run_energy_sweep(&mut world, &mut repo, &inputs, 1);
    let analysis = jobs.last().unwrap();
    assert_eq!(analysis.state, CiJobState::Success, "{:?}", analysis.log);
    let csv = analysis.artifact("energy.csv").unwrap().to_string();
    let sidecar = analysis.artifact("energy.json").unwrap().to_string();
    (world, csv, sidecar)
}

/// The core §11 equivalence: interleaved dispatch changes *when* points
/// run, never *what* they measure — byte-identical analysis artifacts —
/// and the concurrent sweep finishes in strictly less simulated time.
#[test]
fn concurrent_sweep_matches_sequential_and_is_faster() {
    let (con_world, con_csv, con_json) = direct_sweep(true);
    let (seq_world, seq_csv, seq_json) = direct_sweep(false);
    assert_eq!(con_csv, seq_csv, "energy.csv must be dispatch-independent");
    assert_eq!(con_json, seq_json, "energy.json must be dispatch-independent");
    assert!(
        con_world.now() < seq_world.now(),
        "concurrent {} vs sequential {} simulated s",
        con_world.now().0,
        seq_world.now().0
    );
    // all six points actually ran as batch jobs, in both modes
    assert_eq!(con_world.batch.get("jedi").unwrap().records().len(), 6);
    assert_eq!(seq_world.batch.get("jedi").unwrap().records().len(), 6);
    // in concurrent mode every point submitted at the shared instant
    let submits: Vec<i64> = con_world
        .batch
        .get("jedi")
        .unwrap()
        .records()
        .iter()
        .map(|r| r.submit_time.0)
        .collect();
    assert!(submits.windows(2).all(|w| w[0] == w[1]), "{submits:?}");
}

/// The sidecar is well-formed, NaN-free, and never leaks into
/// report.json; `energy_j`/`edp` flow into the tracking history; the
/// world-level sweet-spot table renders the recorded sweep.
#[test]
fn sweep_sidecar_and_tracking_wiring() {
    let (world, csv, sidecar) = run_sweep_pipeline(true);
    let doc = Json::parse(&sidecar).unwrap();
    assert_eq!(doc.str_of("component"), Some("energy-sweep@v1"));
    assert_eq!(doc.str_of("prefix"), Some("jedi.eapp"));
    assert_eq!(doc.str_of("machine"), Some("jedi"));
    assert_eq!(doc.str_of("metric"), Some("energy_j"));
    assert_eq!(doc.get("points").and_then(Json::as_arr).unwrap().len(), 6);
    for key in [
        "sweet_spot_mhz",
        "edp_sweet_spot_mhz",
        "nominal_mhz",
        "energy_nominal_j",
        "energy_sweet_spot_j",
        "saving_vs_nominal",
    ] {
        let v = doc.f64_of(key).unwrap_or(f64::NAN);
        assert!(v.is_finite(), "{key} must be finite, got {v}");
    }
    assert!(!csv.contains("NaN"), "{csv}");
    assert!(!sidecar.contains("NaN"), "{sidecar}");
    // sidecar stays out of recorded history: no report.json carries it
    let repo = world.repo("eapp").unwrap();
    for (path, content) in repo.store.read_all("exacb.data", "") {
        if path.ends_with("report.json") {
            assert!(!content.contains("sweet_spot_mhz"), "{path} leaked analysis");
        }
    }
    // recorded energy metrics are trackable series (→ regression gate)
    let energy = world.track_table("energy_j");
    assert_eq!(energy.rows.len(), 6, "one series per frequency: {:?}", energy.rows);
    let edp = world.track_table("edp");
    assert_eq!(edp.rows.len(), 6, "{:?}", edp.rows);
    // the a-posteriori sweet-spot view
    let t = world.energy_table();
    assert_eq!(t.rows.len(), 1, "{:?}", t.rows);
    assert_eq!(t.rows[0][0], "jedi.eapp");
    assert_eq!(t.rows[0][1], "jedi");
    assert_eq!(t.rows[0][2], "6");
}

fn tiny_app(name: &str, declared: Maturity) -> OnboardingApp {
    OnboardingApp {
        app: PortfolioApp {
            name: name.to_string(),
            domain: "materials".to_string(),
            maturity: declared,
            model: AppModel {
                name: name.to_string(),
                gflops_total: 60_000.0,
                serial_frac: 0.01,
                mem_bound: 0.5,
                comm_mb: 0.0,
                steps: 10,
                weak: false,
            },
            failure_rate: 0.0,
            nodes: 1,
        },
        declared,
        instrument_from: None,
        verify_from: None,
        break_day: None,
        fix_day: None,
    }
}

fn tiny_scenario(apps: Vec<OnboardingApp>) -> OnboardingScenario {
    OnboardingScenario {
        apps,
        days: 1,
        machines: vec!["jedi".to_string()],
        queue: "all".to_string(),
        seed: 55,
        verify_every: 4,
        min_runs: 3,
        min_instrumented: 3,
        window_days: 6,
    }
}

/// Eligibility: the campaign consumes the maturity subsystem's
/// reproducibility-only rule — a non-reproducible application is
/// excluded with its name and held rung in the log.
#[test]
fn campaign_excludes_non_reproducible_apps_by_name() {
    let sc = tiny_scenario(vec![
        tiny_app("golden", Maturity::Reproducibility),
        tiny_app("novice", Maturity::Runnability),
    ]);
    let mut world = World::new(sc.seed);
    study::onboard_declared(&mut world, &sc);
    let out = study::run_energy_campaign(&mut world, &sc, 4, true);

    let swept: Vec<&str> = out.swept.iter().map(|s| s.app.as_str()).collect();
    assert_eq!(swept, vec!["golden"]);
    assert_eq!(
        out.excluded,
        vec![("novice".to_string(), Maturity::Runnability)]
    );
    assert!(
        out.log.iter().any(|l| l.contains("novice") && l.contains("reproducibility")),
        "exclusion must name the app: {:?}",
        out.log
    );
    // the sweep landed as a pipeline record with the sidecar attached
    let sweep = &out.swept[0];
    assert!(sweep.ok);
    let p = world.pipeline(sweep.pipeline_id).unwrap();
    let analysis = p
        .jobs
        .iter()
        .find(|j| j.name.ends_with(".energy-analysis"))
        .unwrap();
    assert_eq!(analysis.state, CiJobState::Success, "{:?}", analysis.log);
    assert!(analysis.artifact("energy.json").is_some());
    // both repositories were restored to the world
    assert!(world.repo("golden").is_some());
    assert!(world.repo("novice").is_some());
    // the excluded app recorded nothing
    assert!(world
        .repo("novice")
        .unwrap()
        .store
        .list("exacb.data", "")
        .is_empty());
}

/// The cache-stash contract: energy points are measurement runs, so a
/// warm re-run of the campaign schedules fresh batch jobs instead of
/// replaying — and the world's cache comes back untouched.
#[test]
fn warm_energy_campaign_schedules_fresh_measurements() {
    let sc = tiny_scenario(vec![tiny_app("golden", Maturity::Reproducibility)]);
    let mut world = World::new(sc.seed);
    world.enable_cache();
    study::onboard_declared(&mut world, &sc);

    let first = study::run_energy_campaign(&mut world, &sc, 4, true);
    assert_eq!(first.swept.len(), 1);
    let jobs_cold = world.batch.get("jedi").unwrap().records().len();
    assert_eq!(jobs_cold, 4, "one batch job per frequency point");

    let second = study::run_energy_campaign(&mut world, &sc, 4, true);
    assert_eq!(second.swept.len(), 1);
    assert_eq!(
        world.batch.get("jedi").unwrap().records().len(),
        2 * jobs_cold,
        "a warm energy campaign must re-measure, never replay"
    );
    // the stash restored the cache and kept it out of the loop entirely
    assert!(world.cache.is_some(), "stashed cache must be restored");
    assert_eq!(world.cache_stats(), exacb::store::CacheStats::default());
}

/// Input-schema validation through the real pipeline path: unknown
/// inputs and unknown machines fail the validate job loudly.
#[test]
fn energy_sweep_schema_validation_is_loud() {
    // unknown input
    let mut world = World::new(3);
    world.add_repo(
        BenchmarkRepo::new("typo")
            .with_file("b.yml", &sweep_jube("typo", 50_000))
            .with_file(
                ".gitlab-ci.yml",
                "include:\n  - component: energy-sweep@v1\n    inputs:\n      prefix: \"jedi.typo\"\n      machine: \"jedi\"\n      jube_file: \"b.yml\"\n      frequencys: []\n",
            ),
    );
    let pid = world.run_pipeline("typo", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(!p.succeeded());
    assert!(
        p.jobs[0].log[0].contains("unknown input 'frequencys'"),
        "{:?}",
        p.jobs[0].log
    );

    // unknown machine: loud name, no execution jobs, no misleading
    // "not enough energy points"
    let mut world = World::new(3);
    world.add_repo(
        BenchmarkRepo::new("ghosted")
            .with_file("b.yml", &sweep_jube("ghosted", 50_000))
            .with_file(
                ".gitlab-ci.yml",
                "include:\n  - component: energy-sweep@v1\n    inputs:\n      prefix: \"ghost.app\"\n      machine: \"ghost\"\n      jube_file: \"b.yml\"\n",
            ),
    );
    let pid = world.run_pipeline("ghosted", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap().clone();
    assert!(!p.succeeded());
    assert_eq!(p.jobs.len(), 1);
    assert!(
        p.jobs[0].log.iter().any(|l| l.contains("unknown machine 'ghost'")),
        "{:?}",
        p.jobs[0].log
    );
    assert!(world.batch.values().all(|b| b.records().is_empty()));
}

/// The energy metrics close the loop with the tracking gate: a planted
/// source change that inflates energy fails `regression-check@v1` on
/// `energy_j` on the inject day, and not before.
#[test]
fn regression_gate_fails_on_planted_energy_regression() {
    const INJECT: i64 = 5;
    let jube = |flops: u64| sweep_jube("egate", flops);
    let ci = "include:\n  - component: execution@v3\n    inputs:\n      prefix: \"jedi.egate\"\n      machine: \"jedi\"\n      queue: \"all\"\n      project: \"cjsc\"\n      budget: \"zam\"\n      jube_file: \"b.yml\"\n      launcher: \"jpwr\"\n  - component: regression-check@v1\n    inputs:\n      prefix: \"jedi.egate\"\n      machine: \"jedi\"\n      queue: \"all\"\n      project: \"cjsc\"\n      budget: \"zam\"\n      jube_file: \"b.yml\"\n      launcher: \"jpwr\"\n      metric: \"energy_j\"\n      threshold_pct: 10\n";
    let mut world = World::new(20260617);
    world.add_repo(
        BenchmarkRepo::new("egate")
            .with_file("b.yml", &jube(100_000))
            .with_file(".gitlab-ci.yml", ci),
    );
    for day in 0..=INJECT {
        world.advance_to(SimTime::from_days(day).add_secs(3 * 3600));
        if day == INJECT {
            // a 40% larger problem is a merge that costs 40% more energy
            let repo = world.repos.get_mut("egate").unwrap();
            for (path, content) in repo.files.iter_mut() {
                if path == "b.yml" {
                    *content = jube(140_000);
                }
            }
            repo.commit = exacb::util::short_hash(b"energy-regression-day");
        }
        let pid = world.run_pipeline("egate", Trigger::Scheduled).unwrap();
        let p = world.pipeline(pid).unwrap();
        let gate = p
            .jobs
            .iter()
            .find(|j| j.name.ends_with(".regression-check"))
            .expect("gate ran");
        let doc = Json::parse(gate.artifact("regressions.json").unwrap()).unwrap();
        assert_eq!(doc.str_of("metric"), Some("energy_j"));
        if day < INJECT {
            assert!(
                p.succeeded(),
                "day {day} must stay green: verdict {:?}, log {:?}",
                doc.str_of("verdict"),
                gate.log
            );
        } else {
            assert!(!p.succeeded(), "inject day must fail the pipeline");
            assert_eq!(
                doc.str_of("verdict"),
                Some("regression"),
                "log: {:?}",
                gate.log
            );
        }
    }
}
