//! Integration: the three-layer compose — AOT HLO artifacts (L1 Pallas
//! kernels inside L2 JAX models) executed from the Rust coordinator via
//! PJRT, inside full CI pipelines. Skips cleanly when `make artifacts`
//! has not run.

use exacb::ci::Trigger;
use exacb::coordinator::{BenchmarkRepo, World};
use exacb::runtime::{manifest::default_dir, Engine};

fn artifacts_built() -> bool {
    default_dir().join("manifest.json").exists()
}

#[test]
fn engine_executes_all_manifest_artifacts() {
    if !artifacts_built() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let Ok(mut eng) = Engine::load_default() else {
        eprintln!("skipped: engine backend unavailable");
        return;
    };
    let entries = eng.manifest.entries.clone();
    assert!(entries.len() >= 7, "expected the full variant grid");
    for e in &entries {
        match e.kind.as_str() {
            "logmap" => {
                let n = e.n();
                let x = vec![0.42f32; n];
                let r = vec![3.3f32; n];
                let (out, summary, wall) = eng.run_logmap(&e.name, &x, &r).unwrap();
                assert_eq!(out.len(), n, "{}", e.name);
                assert!(wall.as_nanos() > 0);
                assert!(summary.iter().all(|v| v.is_finite()));
            }
            "stream" => {
                let (sums, _) = eng.run_stream(&e.name, 0.1).unwrap();
                assert!(sums.iter().all(|v| v.is_finite()));
            }
            other => panic!("unknown artifact kind {other}"),
        }
    }
    assert_eq!(eng.compilations as usize, entries.len());
}

#[test]
fn pjrt_validation_flows_into_protocol_reports() {
    if !artifacts_built() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let mut world = World::new(77);
    if !world.try_attach_engine() {
        eprintln!("skipped: engine backend unavailable");
        return;
    }
    assert!(world.calibration.measured, "host calibration from real runs");
    world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
    let pid = world.run_pipeline("logmap", Trigger::Manual).unwrap();
    assert!(world.pipeline(pid).unwrap().succeeded());
    let repo = world.repo("logmap").unwrap();
    let doc = repo
        .store
        .read("exacb.data", &format!("jedi.logmap/{pid}/report.json"))
        .unwrap();
    let report = exacb::protocol::Report::parse(doc).unwrap();
    let entry = &report.data[0];
    // the run was validated through PJRT, not just modelled
    assert_eq!(
        entry.metrics.str_of("validation"),
        Some("pjrt"),
        "{:?}",
        entry.metrics
    );
    assert!(entry.metric("host_wall_ms").unwrap() > 0.0);
    assert!(entry.metric("host_gflops").unwrap() > 0.0);
}

#[test]
fn stream_validation_through_pipeline() {
    if !artifacts_built() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let mut world = World::new(78);
    if !world.try_attach_engine() {
        eprintln!("skipped: engine backend unavailable");
        return;
    }
    let jube = "name: stream\nsteps:\n  - name: execute\n    remote: true\n    do:\n      - babelstream\n";
    let ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jupiter.stream"
      machine: "jupiter"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
"#;
    world.add_repo(
        BenchmarkRepo::new("stream")
            .with_file("b.yml", jube)
            .with_file(".gitlab-ci.yml", ci),
    );
    let pid = world.run_pipeline("stream", Trigger::Manual).unwrap();
    assert!(world.pipeline(pid).unwrap().succeeded());
    let repo = world.repo("stream").unwrap();
    let doc = repo
        .store
        .read("exacb.data", &format!("jupiter.stream/{pid}/report.json"))
        .unwrap();
    let report = exacb::protocol::Report::parse(doc).unwrap();
    let m = &report.data[0].metrics;
    assert_eq!(m.str_of("validation"), Some("pjrt"));
    // the five Fig. 3 bandwidths are present
    for k in ["bw_copy", "bw_mul", "bw_add", "bw_triad", "bw_dot"] {
        assert!(m.f64_of(k).unwrap() > 0.0, "{k}");
    }
    assert!(m.f64_of("host_stream_gbs").unwrap() > 0.0);
}

#[test]
fn compile_cache_amortises_across_campaign() {
    if !artifacts_built() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let mut world = World::new(79);
    if !world.try_attach_engine() {
        eprintln!("skipped: engine backend unavailable");
        return;
    }
    world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
    for d in 0..5 {
        world.advance_to(exacb::util::timeutil::SimTime::from_days(d).add_secs(7200));
        world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
    }
    let engine = world.engine.as_ref().unwrap();
    // 5 pipelines + calibration runs, but each artifact compiled once
    assert!(engine.executions >= 5);
    assert!(
        engine.compilations <= 3,
        "compilations={} should be bounded by distinct artifacts used",
        engine.compilations
    );
}
