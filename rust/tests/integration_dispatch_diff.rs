//! Differential dispatch property: the indexed O(log n) event loop
//! (`event_loop::drive`) must replay a 100-pipeline × 3-machine campaign
//! **byte-identical** to the retained naive reference scan
//! (`event_loop::drive_reference`) — recorded reports and sidecars,
//! `sacct` records, queue-wait statistics, results tables — under
//! seeded permutations of submission order (each campaign seed reshuffles
//! the work queue, so the pipelines hit the schedulers in a different
//! order every time).
//!
//! The two loops share every other line of code, so any divergence is a
//! dispatch-ordering bug in the indexed implementation. This is the
//! contract that lets the reference scan stay frozen as the executable
//! specification while the fast path evolves.

use exacb::coordinator::{collection, event_loop, postproc, World};
use exacb::workloads::portfolio;

/// Every `sacct` field of every job on every machine, in jobid order.
fn sacct_dump(world: &World) -> String {
    let mut out = String::new();
    for (name, bs) in &world.batch {
        for r in bs.records_iter() {
            out.push_str(&format!(
                "{name} {} {} {:?} {:?} {:?} {} {} {:?}\n",
                r.jobid,
                r.state.name(),
                r.submit_time,
                r.start_time,
                r.end_time,
                r.spec.partition,
                r.spec.nodes,
                r.result
                    .as_ref()
                    .map(|res| (res.success, res.duration_s)),
            ));
        }
    }
    out
}

/// Every file on every branch of every repository store (reports,
/// sidecars, history) — the full recorded state of the campaign.
fn store_dump(world: &World) -> String {
    let mut out = String::new();
    for (name, repo) in &world.repos {
        let mut branches = repo.store.branches();
        branches.sort_unstable();
        for branch in branches {
            for (path, content) in repo.store.read_all(branch, "") {
                out.push_str(&format!("{name} {branch} {path} {}\n", content.len()));
                out.push_str(&content);
                out.push('\n');
            }
        }
    }
    out
}

fn run_campaign(
    seed: u64,
    drive: fn(&mut World, Vec<event_loop::PipelineTask>) -> Vec<u64>,
) -> (String, String, String, Vec<String>, usize, usize) {
    let apps = portfolio::generate(100, seed);
    let machines = ["jedi", "jupiter", "jureca"];
    let mut world = World::new(seed);
    collection::onboard_multi(&mut world, &apps, &machines, "all");
    let summary = collection::run_campaign_concurrent_with(&mut world, &apps, &machines, 1, drive);
    let tables = ["runtime", "tts"]
        .iter()
        .map(|m| postproc::collection_results_table(&world, m).to_csv())
        .collect();
    (
        sacct_dump(&world),
        store_dump(&world),
        postproc::queue_stats(&world).to_csv(),
        tables,
        summary.pipelines_run,
        summary.pipelines_succeeded,
    )
}

/// The named differential property: indexed dispatch replays the
/// campaign byte-identical to the reference scan for several seeds (=
/// several seeded shuffles of the submission order).
#[test]
fn prop_indexed_dispatch_replays_reference_byte_identical() {
    for seed in [11u64, 97, 4242] {
        let fast = run_campaign(seed, event_loop::drive);
        let reference = run_campaign(seed, event_loop::drive_reference);
        assert_eq!(
            fast.4, reference.4,
            "pipelines_run diverged (seed {seed})"
        );
        assert_eq!(
            fast.5, reference.5,
            "pipelines_succeeded diverged (seed {seed})"
        );
        assert_eq!(fast.2, reference.2, "queue stats diverged (seed {seed})");
        assert_eq!(fast.3, reference.3, "results tables diverged (seed {seed})");
        // the heavyweight dumps last: byte-for-byte scheduler records
        // and recorded store state
        assert_eq!(fast.0, reference.0, "sacct records diverged (seed {seed})");
        assert_eq!(fast.1, reference.1, "recorded stores diverged (seed {seed})");
    }
}

/// Sanity: the differential harness actually exercises contention — on
/// a 3-machine fleet with ~33 apps per machine and same-trigger
/// submission, some job must wait beyond the scheduler-latency floor,
/// otherwise the property above would only cover idle timelines.
#[test]
fn differential_campaign_has_real_contention() {
    let (sacct, _, _, _, run, _) = run_campaign(11, event_loop::drive);
    assert_eq!(run, 100);
    let apps = portfolio::generate(100, 11);
    let machines = ["jedi", "jupiter", "jureca"];
    let mut world = World::new(11);
    collection::onboard_multi(&mut world, &apps, &machines, "all");
    collection::run_campaign_concurrent_with(&mut world, &apps, &machines, 1, event_loop::drive);
    let max_wait = world
        .batch
        .values()
        .flat_map(|bs| bs.records_iter().filter_map(|r| r.queue_wait_s()))
        .max()
        .unwrap();
    let latency = world.batch.get("jedi").unwrap().sched_latency_s;
    assert!(
        max_wait > latency,
        "no contention in the differential campaign (max wait {max_wait}s)"
    );
    assert!(!sacct.is_empty());
}
