//! Integration: the digest-indexed snapshot + query layer across the
//! whole stack (DESIGN.md §12) — campaign → store → snapshot →
//! cmp/rank — pinned against the legacy full-walk readers, which
//! survive exactly as the executable differential reference.

use exacb::analysis::ReportSet;
use exacb::coordinator::{collection, World};
use exacb::maturity::{Assessment, CriteriaConfig};
use exacb::query::{self, Engine};
use exacb::store::{sort_rows, Row, Snapshot};
use exacb::tracking::{run_scenario, History};
use exacb::workloads::portfolio;
use exacb::workloads::regression::RegressionScenario;

/// A small but real two-machine campaign world with recorded reports.
fn campaign_world() -> World {
    let apps = portfolio::generate(4, 77);
    let mut world = World::new(77);
    let machines = ["jupiter", "jedi"];
    collection::onboard_multi(&mut world, &apps, &machines, "all");
    collection::run_campaign_concurrent(&mut world, &apps, &machines, 3);
    world
}

/// Every snapshot consumer reproduces its legacy full-walk reference
/// byte-for-byte on a real campaign store: History series, ReportSet
/// contents, maturity Evidence, and the skip counts.
#[test]
fn snapshot_consumers_match_the_legacy_walk() {
    let world = campaign_world();
    let cfg = CriteriaConfig::default();
    let mut repos_with_data = 0;
    for repo in world.repos.values() {
        let (walk_h, walk_h_skip) =
            History::from_store(&repo.store, "exacb.data", "", &["runtime"]);
        let (snap_h, snap_h_skip) =
            repo.with_snapshot(|snap| History::from_snapshot(snap, "", &["runtime"]));
        let flat = |h: &History| -> Vec<_> {
            h.series()
                .into_iter()
                .map(|s| (s.key.clone(), s.points.clone()))
                .collect()
        };
        assert_eq!(flat(&walk_h), flat(&snap_h), "{}", repo.name);
        assert_eq!(walk_h_skip, snap_h_skip);
        if walk_h.total_points() > 0 {
            repos_with_data += 1;
        }

        let (walk_set, walk_set_skip) = ReportSet::load(&repo.store, "exacb.data", "");
        let (snap_set, snap_set_skip) =
            repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, ""));
        assert_eq!(walk_set.reports, snap_set.reports, "{}", repo.name);
        assert_eq!(walk_set_skip, snap_set_skip);

        let (walk_a, walk_a_skip) = Assessment::from_store(&repo.store, "exacb.data", "", &cfg);
        let (snap_a, snap_a_skip) =
            repo.with_snapshot(|snap| Assessment::from_snapshot(snap, "", &cfg));
        assert_eq!(walk_a.evidence(None), snap_a.evidence(None), "{}", repo.name);
        assert_eq!(walk_a_skip, snap_a_skip);
    }
    assert!(repos_with_data > 0, "campaign recorded nothing — vacuous test");
}

/// A snapshot refreshed mid-campaign is byte-identical to one built
/// from scratch at the end, and the shared repo snapshot is built
/// exactly once (every later read pays O(delta)).
#[test]
fn mid_campaign_refresh_matches_a_fresh_build() {
    let apps = portfolio::generate(3, 5);
    let mut world = World::new(5);
    collection::onboard_multi(&mut world, &apps, &["jupiter"], "all");
    collection::run_campaign_concurrent(&mut world, &apps, &["jupiter"], 2);
    // touch every repo's snapshot mid-campaign so the final read is a
    // refresh over the second half of the history
    for repo in world.repos.values() {
        repo.with_snapshot(|snap| assert_eq!(snap.rebuilds(), 1));
    }
    collection::run_campaign_concurrent(&mut world, &apps, &["jupiter"], 2);
    for repo in world.repos.values() {
        let refreshed = repo.with_snapshot(|snap| snap.fingerprint());
        let scratch = Snapshot::build(&repo.store, "exacb.data").fingerprint();
        assert_eq!(refreshed, scratch, "{}", repo.name);
        let (rebuilds, consumed) = repo.snapshot_stats();
        assert_eq!(rebuilds, 1, "{}: refresh escalated to a rebuild", repo.name);
        assert!(consumed > 0, "{}: no commits consumed", repo.name);
    }
}

/// `cmp --by commit` on a planted regression: the runtime group of the
/// post-injection commit is `slower`, with a Welch interval entirely
/// above zero naming the shift.
#[test]
fn cmp_names_the_interval_on_a_planted_regression() {
    let sc = RegressionScenario::planted("jedi", 12, 7, 10.0, 20260301);
    let mut world = World::new(20260301);
    run_scenario(&mut world, &sc);
    let mut rows = query::world_rows(&world);
    rows.retain(|r| r.metric == "runtime");
    let commits = query::commits_by_first_seen(&rows);
    assert_eq!(commits.len(), 2, "planted scenario must record exactly two commits");
    let report = query::compare(&rows, Engine::Commit, &commits[0], &commits[1], 0.95, 4);
    assert!(!report.rows.is_empty());
    let slower: Vec<_> = report.rows.iter().filter(|r| r.verdict == "slower").collect();
    assert!(!slower.is_empty(), "10% planted shift not flagged: {:?}", report.rows);
    for r in &slower {
        let i = r.interval.as_ref().expect("slower verdict requires an interval");
        assert!(i.entirely_above(0.0), "{:?}", i);
        assert!(r.speedup < 1.0, "candidate is the slow side: {}", r.speedup);
    }
    // the reverse comparison is the mirror image
    let rev = query::compare(&rows, Engine::Commit, &commits[1], &commits[0], 0.95, 4);
    assert_eq!(report.count("slower"), rev.count("faster"));
}

/// The whole portfolio on *each* machine (a multi-machine onboarding
/// would round-robin apps, leaving no workload shared), canonical order
/// — the row set `exacb cmp`/`exacb rank` query in machine mode.
fn portfolio_rows(machines: &[&str], n: usize, days: i64, seed: u64) -> Vec<Row> {
    let apps = portfolio::generate(n, seed);
    let mut rows = Vec::new();
    for m in machines {
        let mut world = World::new(seed);
        collection::onboard_multi(&mut world, &apps, &[m], "all");
        collection::run_campaign_concurrent(&mut world, &apps, &[m], days);
        rows.extend(query::world_rows(&world));
    }
    sort_rows(&mut rows);
    rows
}

/// Satellite property: cmp and rank results are independent of both the
/// shard count and the ingestion order of the row set (any permutation
/// canonicalises to the same query input).
#[test]
fn queries_are_shard_and_ingestion_order_independent() {
    let rows = portfolio_rows(&["jupiter", "jedi"], 3, 2, 7);
    assert!(!rows.is_empty());
    // a hostile permutation: reverse, then re-canonicalise
    let mut permuted: Vec<_> = rows.iter().rev().cloned().collect();
    sort_rows(&mut permuted);
    assert_eq!(rows, permuted, "sort_rows is not a canonical order");

    let cmp_base = query::compare(&rows, Engine::Machine, "jupiter", "jedi", 0.95, 1);
    assert!(!cmp_base.rows.is_empty(), "no shared workload groups — vacuous test");
    let cmp_ref = cmp_base.table().render();
    let rank_ref = query::rank(&rows, Engine::Machine, 1);
    assert!(!rank_ref.groups.is_empty());
    for (shards, input) in [(1, &permuted), (8, &rows), (64, &permuted)] {
        let c = query::compare(input, Engine::Machine, "jupiter", "jedi", 0.95, shards);
        assert_eq!(c.table().render(), cmp_ref, "cmp diverged at shards={shards}");
        let r = query::rank(input, Engine::Machine, shards);
        assert_eq!(r.groups, rank_ref.groups, "rank diverged at shards={shards}");
        assert_eq!(r.aggregate, rank_ref.aggregate);
    }
    // exports are a pure function of the canonical row set
    assert_eq!(
        query::rows_to_csv(&rows),
        query::rows_to_csv(&permuted),
        "CSV export is ingestion-order dependent"
    );
    assert_eq!(
        query::rows_to_json(&rows).pretty(),
        query::rows_to_json(&permuted).pretty()
    );
}

/// The gate-facing read path is O(delta): interleaving campaign days
/// with longitudinal reads never rebuilds the snapshot after its first
/// construction.
#[test]
fn interleaved_reads_never_rebuild() {
    let sc = RegressionScenario::control("jedi", 6, 9);
    let mut world = World::new(9);
    run_scenario(&mut world, &sc);
    // several distinct readers over the same shared snapshot
    let t1 = world.track_table("runtime").render();
    let _ = world.track_table("runtime");
    let repo = world.repo(&sc.app).unwrap();
    let (hist, _) = repo.with_snapshot(|snap| History::from_snapshot(snap, "", &["runtime"]));
    assert!(hist.total_points() > 0);
    assert!(t1.contains("jedi"));
    let (rebuilds, _) = repo.snapshot_stats();
    assert_eq!(rebuilds, 1, "a reader forced a full rebuild");
}
