//! Property-based integration tests on coordinator invariants
//! (DESIGN.md §7), via the in-repo property harness (util::prop).

use exacb::prop_assert;
use exacb::protocol::{DataEntry, Report};
use exacb::scheduler::{AccountManager, BatchSystem, JobResult, JobSpec};
use exacb::util::json::Json;
use exacb::util::prop::{check, Gen};
use exacb::util::timeutil::SimTime;

/// The scheduler never over-allocates nodes: at every point of a random
/// submission schedule, running jobs' nodes never exceed the partition.
#[test]
fn prop_scheduler_never_overallocates() {
    check("scheduler never over-allocates", 60, |g: &mut Gen| {
        let total_nodes = g.u64(2, 16);
        let mut bs = BatchSystem::new("m", 64, AccountManager::open("a", "b", 1e12));
        bs.add_partition("p", total_nodes);
        let n_jobs = g.usize(1, 12);
        let mut ids = Vec::new();
        for _ in 0..n_jobs {
            let nodes = g.u64(1, total_nodes);
            let dur = g.u64(1, 5000) as f64;
            if let Ok(id) = bs.submit(
                JobSpec {
                    nodes,
                    account: "a".into(),
                    budget: "b".into(),
                    partition: "p".into(),
                    walltime_limit_s: 100_000,
                    ..Default::default()
                },
                Box::new(move |_| JobResult {
                    duration_s: dur,
                    success: true,
                    metrics: Json::obj(),
                    files: vec![],
                }),
            ) {
                ids.push(id);
            }
        }
        bs.run_until_idle();
        // after the fact, verify no overlap ever exceeded capacity by
        // sweeping start/end events
        let mut events: Vec<(i64, i64)> = Vec::new(); // (time, +/- nodes)
        for id in &ids {
            let r = bs.record(*id).unwrap();
            let (Some(s), Some(e)) = (r.start_time, r.end_time) else {
                continue;
            };
            events.push((s.0, r.spec.nodes as i64));
            events.push((e.0, -(r.spec.nodes as i64)));
        }
        events.sort_by_key(|&(t, d)| (t, d)); // process releases before grabs at same t
        let mut in_use = 0i64;
        for (t, d) in events {
            in_use += d;
            prop_assert!(
                in_use <= total_nodes as i64,
                "over-allocation at t={t}: {in_use} > {total_nodes}"
            );
        }
        // all jobs eventually completed
        for id in &ids {
            prop_assert!(
                bs.record(*id).unwrap().state.is_terminal(),
                "job {id} not terminal"
            );
        }
        Ok(())
    });
}

/// Budget accounting conserves core-hours: total charged equals the sum
/// over completed jobs of nodes × cores × duration.
#[test]
fn prop_budget_conservation() {
    check("budget accounting conserves core-hours", 40, |g: &mut Gen| {
        let cores = g.u64(16, 128);
        let mut bs = BatchSystem::new("m", cores, AccountManager::open("a", "b", 1e12));
        bs.add_partition("p", 8);
        let n = g.usize(1, 8);
        let mut ids = Vec::new();
        for _ in 0..n {
            let dur = g.u64(10, 2000) as f64;
            let id = bs
                .submit(
                    JobSpec {
                        nodes: g.u64(1, 4),
                        account: "a".into(),
                        budget: "b".into(),
                        partition: "p".into(),
                        walltime_limit_s: 100_000,
                        ..Default::default()
                    },
                    Box::new(move |_| JobResult {
                        duration_s: dur,
                        success: true,
                        metrics: Json::obj(),
                        files: vec![],
                    }),
                )
                .unwrap();
            ids.push(id);
        }
        bs.run_until_idle();
        let expected: f64 = ids
            .iter()
            .map(|id| bs.record(*id).unwrap().core_hours(cores))
            .sum();
        let charged = bs.accounts.total_used();
        prop_assert!(
            (charged - expected).abs() < 1e-6 * expected.max(1.0),
            "charged {charged} != expected {expected}"
        );
        Ok(())
    });
}

/// Protocol documents round-trip: parse(write(r)) == r for arbitrary
/// generated reports.
#[test]
fn prop_protocol_roundtrip() {
    check("protocol round-trips", 80, |g: &mut Gen| {
        let mut r = Report::default();
        r.reporter.tool = g.ident(8);
        r.reporter.tool_version = format!("{}.{}", g.u64(0, 9), g.u64(0, 99));
        r.reporter.system = g.ident(10);
        r.reporter.timestamp = SimTime(g.i64(0, 10_000_000)).iso8601();
        // protocol numbers are JSON numbers (f64): integers are exact up
        // to 2^53, which the schema documents as the id range
        r.reporter.pipeline_id = g.u64(0, 1 << 40);
        r.reporter.seed = g.u64(0, 1 << 50);
        r.experiment.system = r.reporter.system.clone();
        r.experiment.variant = g.ident(6);
        r.experiment.timestamp = r.reporter.timestamp.clone();
        r.parameter = Json::obj().set(&g.ident(5), g.u64(0, 100));
        let n = g.usize(0, 6);
        for _ in 0..n {
            let mut metrics = Json::obj();
            for _ in 0..g.usize(0, 4) {
                metrics.insert(&g.ident(6), Json::Num(g.f64(-1e6, 1e6)));
            }
            r.data.push(DataEntry {
                success: g.bool(),
                runtime: g.f64(0.0, 1e5),
                nodes: g.u64(1, 4096),
                taskspernode: g.u64(1, 8),
                threadspertask: g.u64(1, 64),
                jobid: g.u64(0, 1 << 40),
                queue: g.ident(8),
                metrics,
            });
        }
        let doc = r.to_document();
        let back = Report::parse(&doc).map_err(|e| exacb::util::prop::PropFail {
            msg: format!("parse failed: {e} for doc {doc}"),
        })?;
        prop_assert!(back == r, "round-trip mismatch");
        Ok(())
    });
}

/// Store commits are immutable and prefix listing is complete: every
/// committed path remains readable with its exact content at head when
/// not overwritten.
#[test]
fn prop_store_retains_latest_writes() {
    check("store retains latest writes", 40, |g: &mut Gen| {
        let mut store = exacb::store::DataStore::new();
        let mut latest: std::collections::BTreeMap<String, String> = Default::default();
        let commits = g.usize(1, 10);
        for c in 0..commits {
            let n_files = g.usize(1, 4);
            let mut files = Vec::new();
            for _ in 0..n_files {
                let path = format!("p{}/f{}", g.usize(0, 2), g.usize(0, 5));
                let content = format!("v{}", g.u64(0, 1_000_000));
                latest.insert(path.clone(), content.clone());
                files.push((path, content));
            }
            store.commit("exacb.data", &files, &format!("c{c}"), SimTime(c as i64));
        }
        for (path, content) in &latest {
            let got = store.read("exacb.data", path).map_err(|e| {
                exacb::util::prop::PropFail {
                    msg: format!("read {path}: {e}"),
                }
            })?;
            prop_assert!(got == content, "{path}: got {got}, want {content}");
        }
        let listed = store.list("exacb.data", "");
        prop_assert!(
            listed.len() == latest.len(),
            "listing {} != expected {}",
            listed.len(),
            latest.len()
        );
        Ok(())
    });
}

/// Cache keys are canonical: the digest is independent of the order in
/// which parts are supplied — direct insertion order, reversed, or via
/// `BTreeMap` iteration after a JSON re-serialization round trip all
/// produce the same key.
#[test]
fn prop_cache_key_digest_is_stable() {
    use exacb::store::CacheKeyBuilder;
    check("cache key digest is order-stable", 60, |g: &mut Gen| {
        let n = g.usize(1, 8);
        let pairs: Vec<(String, String)> = (0..n)
            .map(|i| (format!("k{i}_{}", g.ident(6)), format!("v{}", g.u64(0, 100_000))))
            .collect();
        let build = |parts: &[(String, String)]| {
            let mut b = CacheKeyBuilder::new("bench", "step");
            for (k, v) in parts {
                b = b.field(k, v);
            }
            b.build()
        };
        let direct = build(&pairs);
        let mut reversed = pairs.clone();
        reversed.reverse();
        prop_assert!(build(&reversed) == direct, "reversal changed the digest");
        // BTreeMap iteration order (sorted) after a serialization round trip
        let mut obj = Json::obj();
        for (k, v) in &pairs {
            obj.insert(k, v.as_str());
        }
        let reparsed = Json::parse(&obj.pretty()).map_err(|e| {
            exacb::util::prop::PropFail {
                msg: format!("reparse: {e}"),
            }
        })?;
        let via_map: std::collections::BTreeMap<String, String> = reparsed
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.as_str().unwrap().to_string()))
            .collect();
        let from_map: Vec<(String, String)> = via_map.into_iter().collect();
        prop_assert!(
            build(&from_map) == direct,
            "BTreeMap round trip changed the digest"
        );
        Ok(())
    });
}

/// Distinct resolved steps never collide on a digest (128-bit keys; a
/// random collision here would mean replaying the wrong result).
#[test]
fn prop_distinct_cache_keys_never_collide() {
    use exacb::store::CacheKeyBuilder;
    check("distinct cache keys never collide", 40, |g: &mut Gen| {
        let n = g.usize(2, 40);
        let mut seen_desc = std::collections::HashSet::new();
        let mut seen_digest = std::collections::HashSet::new();
        let mut seen_slot_for: std::collections::HashMap<String, String> = Default::default();
        for _ in 0..n {
            let bench = g.ident(5);
            let step = g.ident(5);
            let machine = (*g.pick(&["jedi", "jupiter", "jureca"])).to_string();
            let cmd = format!(
                "app --flops {} --steps {}",
                g.u64(0, 1_000_000),
                g.u64(1, 100)
            );
            let desc = format!("{bench}|{step}|{machine}|{cmd}");
            if !seen_desc.insert(desc.clone()) {
                continue; // duplicate resolved step, same key is correct
            }
            let key = CacheKeyBuilder::new(&bench, &step)
                .ident("machine", &machine)
                .field("commands", &cmd)
                .build();
            prop_assert!(
                seen_digest.insert(key.digest.clone()),
                "digest collision for {desc}"
            );
            // same identity must keep the same slot; the slot ignores fields
            let ident = format!("{bench}|{step}|{machine}");
            match seen_slot_for.get(&ident) {
                Some(slot) => prop_assert!(slot == &key.slot, "slot moved for {ident}"),
                None => {
                    seen_slot_for.insert(ident, key.slot.clone());
                }
            }
        }
        Ok(())
    });
}

/// `store::git` history stays immutable while the execution cache reads
/// and writes around it: every head snapshot taken during a cached
/// campaign is still byte-reconstructible afterwards.
#[test]
fn prop_store_history_immutable_under_cache_writes() {
    use exacb::ci::Trigger;
    use exacb::coordinator::{BenchmarkRepo, World};
    check("git history immutable under cache writes", 8, |g: &mut Gen| {
        let mut world = World::new(g.u64(0, 1 << 30));
        world.enable_cache();
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        let n = g.usize(2, 6);
        let mut snapshots = Vec::new();
        for day in 0..n {
            if g.bool() {
                world.advance_to(SimTime::from_days(day as i64).add_secs(3 * 3600));
            }
            world
                .run_pipeline("logmap", Trigger::Scheduled)
                .map_err(|e| exacb::util::prop::PropFail { msg: e })?;
            let repo = world.repo("logmap").unwrap();
            let head = repo.store.head("exacb.data").unwrap();
            snapshots.push((
                head.id.clone(),
                repo.store.head_tree("exacb.data").unwrap().clone(),
            ));
        }
        let repo = world.repo("logmap").unwrap();
        prop_assert!(
            repo.store.history("exacb.data").len() == n,
            "expected {n} commits"
        );
        for (id, tree) in &snapshots {
            let got = repo.store.tree_at(id);
            prop_assert!(got.is_some(), "commit {id} vanished");
            prop_assert!(
                &got.unwrap() == tree,
                "tree for {id} changed after cache writes"
            );
        }
        Ok(())
    });
}

/// Harness expansion × executor: the number of scheduler jobs equals the
/// size of the parameter cross product, whatever the axes.
#[test]
fn prop_expansion_matches_job_count() {
    use exacb::ci::Trigger;
    use exacb::coordinator::{BenchmarkRepo, World};
    check("expansion size == scheduler job count", 12, |g: &mut Gen| {
        let n_nodes_vals = g.usize(1, 3);
        let n_steps_vals = g.usize(1, 3);
        let nodes_vals: Vec<String> = (0..n_nodes_vals).map(|i| (1u64 << i).to_string()).collect();
        let steps_vals: Vec<String> =
            (0..n_steps_vals).map(|i| (10 * (i + 1)).to_string()).collect();
        let jube = format!(
            "name: px\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        values: [{}]\n      - name: steps\n        values: [{}]\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name px --flops 1000 --steps $steps\n",
            nodes_vals.join(", "),
            steps_vals.join(", ")
        );
        let ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jedi.px"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
"#;
        let mut world = World::new(g.u64(0, 1 << 30));
        world.add_repo(
            BenchmarkRepo::new("px")
                .with_file("b.yml", &jube)
                .with_file(".gitlab-ci.yml", ci),
        );
        world.run_pipeline("px", Trigger::Manual).map_err(|e| {
            exacb::util::prop::PropFail { msg: e }
        })?;
        let jobs = world.batch.get("jedi").unwrap().records().len();
        let expect = n_nodes_vals * n_steps_vals;
        prop_assert!(
            jobs == expect,
            "submitted {jobs} scheduler jobs for a {expect}-point study"
        );
        Ok(())
    });
}

/// The fault schedule is a pure function of `(seed, machine, jobid)`:
/// deciding the same jobids in any order, at any wall-clock instant,
/// yields identical fates — submission-order permutations cannot move a
/// fault from one job to another. The retry backoff is equally pure and
/// stays inside its documented `[30 s, 300 s]` bound.
#[test]
fn prop_fault_schedule_is_pure() {
    use exacb::scheduler::{backoff_s, FaultPlan};
    check("fault schedule is pure and order-free", 60, |g: &mut Gen| {
        let machine = *g.pick(&["jedi", "jupiter", "jureca"]);
        let plan = FaultPlan {
            node_fail_rate: g.f64(0.0, 0.5),
            preempt_rate: g.f64(0.0, 0.5),
            ..FaultPlan::seeded(machine, g.u64(0, 1 << 40))
        };
        let jobids: Vec<u64> = (0..g.usize(5, 40))
            .map(|_| g.u64(7_700_000, 7_900_000))
            .collect();
        let t1 = SimTime(g.i64(0, 10_000_000));
        let t2 = SimTime(g.i64(0, 10_000_000));
        let forward: Vec<_> = jobids.iter().map(|&j| plan.decide(j, "app", t1)).collect();
        let mut backward: Vec<_> = jobids
            .iter()
            .rev()
            .map(|&j| plan.decide(j, "app", t2))
            .collect();
        backward.reverse();
        for ((j, a), b) in jobids.iter().zip(&forward).zip(&backward) {
            prop_assert!(
                a == b,
                "job {j}: fate depends on decision order or time ({a:?} vs {b:?})"
            );
        }
        let attempt = g.u64(0, 5) as u32;
        let b = backoff_s(machine, "execute", attempt);
        prop_assert!(
            b == backoff_s(machine, "execute", attempt),
            "backoff is not pure"
        );
        prop_assert!((30..=300).contains(&b), "backoff {b} outside [30, 300]");
        Ok(())
    });
}

/// Preemption + requeue preserves measurement streams: the payload runs
/// exactly once, and the requeued twin publishes a result byte-equal to
/// what an unpreempted run of the same job would have published — the
/// fault model never re-rolls an application measurement.
#[test]
fn prop_requeue_preserves_payload_streams() {
    use exacb::scheduler::{FaultKind, FaultPlan, ForcedFault, JobState, Window};
    use std::cell::Cell;
    use std::rc::Rc;
    check("requeue preserves payload streams", 30, |g: &mut Gen| {
        let dur = g.u64(50, 5000) as f64;
        let metric = g.u64(0, 1_000_000);
        let name = format!("exacb-{}-execute", g.ident(6));
        let run = |forced: bool| {
            let calls = Rc::new(Cell::new(0u32));
            let calls_in = Rc::clone(&calls);
            let mut bs = BatchSystem::new("m", 64, AccountManager::open("a", "b", 1e12));
            bs.add_partition("p", 4);
            if forced {
                let mut plan = FaultPlan::quiet("m");
                plan.forced.push(ForcedFault {
                    name_contains: name.clone(),
                    window: Window::new(SimTime(0), SimTime::from_days(10_000)),
                    kind: FaultKind::Preempt,
                });
                bs.set_fault_plan(Some(plan));
            }
            let id = bs
                .submit(
                    JobSpec {
                        name: name.clone(),
                        nodes: 1,
                        account: "a".into(),
                        budget: "b".into(),
                        partition: "p".into(),
                        walltime_limit_s: 100_000,
                        ..Default::default()
                    },
                    Box::new(move |_| {
                        calls_in.set(calls_in.get() + 1);
                        JobResult {
                            duration_s: dur,
                            success: true,
                            metrics: Json::obj().set("val", metric),
                            files: vec![],
                        }
                    }),
                )
                .unwrap();
            bs.run_until_idle();
            (calls.get(), id, bs)
        };

        let (quiet_calls, quiet_id, quiet_bs) = run(false);
        let quiet_rec = quiet_bs.record(quiet_id).unwrap();
        prop_assert!(quiet_calls == 1, "unfaulted payload ran {quiet_calls}x");
        prop_assert!(quiet_rec.state == JobState::Completed, "{:?}", quiet_rec.state);

        let (calls, id, bs) = run(true);
        prop_assert!(calls == 1, "requeue re-ran the payload ({calls}x)");
        let original = bs.record(id).unwrap();
        prop_assert!(
            original.state == JobState::Preempted,
            "forced preemption missed: {:?}",
            original.state
        );
        let twin_id = original
            .result
            .as_ref()
            .and_then(|r| r.metrics.u64_of("requeued_as"))
            .ok_or(exacb::util::prop::PropFail {
                msg: "preempted record has no requeued_as".into(),
            })?;
        let twin = bs.record(twin_id).ok_or(exacb::util::prop::PropFail {
            msg: format!("twin {twin_id} has no record"),
        })?;
        prop_assert!(twin.state == JobState::Completed, "{:?}", twin.state);
        let twin_res = twin.result.as_ref().unwrap();
        let quiet_res = quiet_rec.result.as_ref().unwrap();
        prop_assert!(
            twin_res.success
                && twin_res.duration_s == quiet_res.duration_s
                && twin_res.metrics.u64_of("val") == Some(metric),
            "requeued result diverged from the unpreempted run: {twin_res:?} vs {quiet_res:?}"
        );
        Ok(())
    });
}

/// Arming the all-zero-rate fault plan is byte-identical to never
/// installing a plan at all, across whole multi-day campaigns: same
/// `sacct` records, same recorded store bytes.
#[test]
fn prop_zero_rate_fault_plan_is_inert() {
    use exacb::ci::Trigger;
    use exacb::coordinator::{BenchmarkRepo, World};
    use exacb::scheduler::FaultPlan;

    fn dump(world: &World) -> String {
        let mut out = String::new();
        for (name, bs) in &world.batch {
            for r in bs.records_iter() {
                out.push_str(&format!(
                    "{name} {} {} {:?} {:?} {:?} {:?}\n",
                    r.jobid,
                    r.state.name(),
                    r.submit_time,
                    r.start_time,
                    r.end_time,
                    r.result.as_ref().map(|res| (res.success, res.duration_s)),
                ));
            }
        }
        for (name, repo) in &world.repos {
            let mut branches = repo.store.branches();
            branches.sort_unstable();
            for branch in branches {
                for (path, content) in repo.store.read_all(branch, "") {
                    out.push_str(&format!("{name} {branch} {path}\n{content}\n"));
                }
            }
        }
        out
    }

    check("zero-rate fault plan is byte-inert", 6, |g: &mut Gen| {
        let seed = g.u64(0, 1 << 30);
        let days = g.usize(1, 3) as i64;
        let run = |armed: bool| -> Result<String, exacb::util::prop::PropFail> {
            let mut world = World::new(seed);
            world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
            if armed {
                world
                    .batch
                    .get_mut("jedi")
                    .unwrap()
                    .set_fault_plan(Some(FaultPlan::quiet("jedi")));
            }
            for day in 0..days {
                world.advance_to(SimTime::from_days(day).add_secs(3 * 3600));
                world
                    .run_pipeline("logmap", Trigger::Scheduled)
                    .map_err(|e| exacb::util::prop::PropFail { msg: e })?;
            }
            Ok(dump(&world))
        };
        prop_assert!(
            run(true)? == run(false)?,
            "arming the quiet plan changed recorded bytes (seed {seed}, {days} days)"
        );
        Ok(())
    });
}
