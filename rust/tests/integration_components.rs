//! Integration: multi-component pipelines — execution followed by
//! post-processing components in a single CI configuration, dispatched
//! through the world exactly as a repository's `.gitlab-ci.yml` wires
//! them (paper §V-A: execution and post-processing orchestrators are
//! separate, communicate only via recorded protocol data).

use exacb::ci::{CiJobState, Trigger};
use exacb::coordinator::{BenchmarkRepo, World};
use exacb::util::table::Table;
use exacb::util::timeutil::SimTime;

/// Repo whose single pipeline executes a scaling study AND runs the
/// scalability post-processor over the freshly recorded data.
fn combined_repo() -> BenchmarkRepo {
    let jube = "name: combo\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        values: [1, 2, 4, 8, 16]\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name combo --flops 300000 --comm-mb 48 --steps 100\n";
    let ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jedi.combo"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
  - component: scalability@v3
    inputs:
      prefix: "jedi.combo.scaling"
      selector: "jedi.combo"
      mode: "strong"
"#;
    BenchmarkRepo::new("combo")
        .with_file("b.yml", jube)
        .with_file(".gitlab-ci.yml", ci)
}

#[test]
fn execute_then_postprocess_in_one_pipeline() {
    let mut world = World::new(21);
    world.add_repo(combined_repo());
    let pid = world.run_pipeline("combo", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(
        p.succeeded(),
        "{:?}",
        p.jobs.iter().map(|j| (&j.name, j.state, &j.log)).collect::<Vec<_>>()
    );
    // stages: setup, execute, record, scalability
    assert_eq!(p.jobs.len(), 4);
    let scaling = p.job("jedi.combo.scaling.scalability").unwrap();
    assert_eq!(scaling.state, CiJobState::Success);
    let csv = Table::from_csv(scaling.artifact("scaling.csv").unwrap()).unwrap();
    assert_eq!(csv.len(), 5); // one row per node count
    // efficiency column decays monotonically
    let effs: Vec<f64> = csv
        .column("efficiency")
        .unwrap()
        .iter()
        .map(|v| v.parse().unwrap())
        .collect();
    for w in effs.windows(2) {
        assert!(w[1] <= w[0] + 1e-9, "{effs:?}");
    }
    assert!(scaling.artifact("scaling.svg").unwrap().contains("<svg"));
}

#[test]
fn daily_series_plus_timeseries_component() {
    // a repo that runs daily and post-processes its own series on the
    // last day — the Fig. 3 shape, through the component dispatcher.
    let jube = "name: daily\nsteps:\n  - name: execute\n    remote: true\n    do:\n      - babelstream\n";
    let exec_ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jupiter.daily"
      machine: "jupiter"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
"#;
    let analysis_ci = r#"
include:
  - component: time-series@v3
    inputs:
      prefix: "jupiter.daily"
      data_labels: [ "bw_copy", "bw_triad" ]
      ylabel: [ "Bandwidth / MB/s" ]
"#;
    let mut world = World::new(22);
    world.add_repo(
        BenchmarkRepo::new("daily")
            .with_file("b.yml", jube)
            .with_file(".gitlab-ci.yml", exec_ci),
    );
    for d in 0..8 {
        world.advance_to(SimTime::from_days(d).add_secs(3 * 3600));
        world.run_pipeline("daily", Trigger::Scheduled).unwrap();
    }
    // switch the repo's CI config to the analysis component (a commit
    // changing .gitlab-ci.yml) and run once more
    {
        let repo = world.repos.get_mut("daily").unwrap();
        for (path, content) in repo.files.iter_mut() {
            if path == ".gitlab-ci.yml" {
                *content = analysis_ci.to_string();
            }
        }
    }
    let pid = world.run_pipeline("daily", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(p.succeeded());
    let job = &p.jobs[0];
    let csv = Table::from_csv(job.artifact("timeseries.csv").unwrap()).unwrap();
    assert_eq!(csv.len(), 2); // two labels
    assert_eq!(csv.rows[0][1], "8"); // 8 daily points each
    // stable verdict for both kernels on an event-free machine
    assert_eq!(csv.rows[0][5], "true");
    assert_eq!(csv.rows[1][5], "true");
}

#[test]
fn component_catalog_rejects_unvalidated_pipelines_early() {
    // typo'd input never reaches the scheduler
    let ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "x"
      machine: "jedi"
      jube_file: "b.yml"
      qeueu: "all"
"#;
    let mut world = World::new(23);
    world.add_repo(
        BenchmarkRepo::new("typo")
            .with_file("b.yml", "name: t\nsteps:\n  - name: e\n    do: [true]\n")
            .with_file(".gitlab-ci.yml", ci),
    );
    let pid = world.run_pipeline("typo", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(!p.succeeded());
    assert!(p.jobs[0].log[0].contains("unknown input 'qeueu'"), "{:?}", p.jobs[0].log);
    // nothing was submitted to any batch system
    for bs in world.batch.values() {
        assert_eq!(bs.records().len(), 0);
    }
}

#[test]
fn energy_component_through_dispatcher() {
    let jube = "name: en\nsteps:\n  - name: execute\n    remote: true\n    do:\n      - simapp --name en --flops 150000 --membound 0.5 --steps 30\n";
    let ci = r#"
include:
  - component: jureap/energy@v3
    inputs:
      prefix: "jedi.en"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
      frequencies: [495, 990, 1485, 1980]
"#;
    let mut world = World::new(24);
    world.add_repo(
        BenchmarkRepo::new("en")
            .with_file("b.yml", jube)
            .with_file(".gitlab-ci.yml", ci),
    );
    let pid = world.run_pipeline("en", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    // 4 frequencies x 3 stages + 1 analysis job
    assert_eq!(p.jobs.len(), 13, "{:?}", p.jobs.iter().map(|j| &j.name).collect::<Vec<_>>());
    let analysis = p.jobs.last().unwrap();
    assert_eq!(analysis.state, CiJobState::Success, "{:?}", analysis.log);
    let spot = analysis.output.f64_of("sweet_spot_mhz").unwrap();
    assert!([495.0, 990.0, 1485.0].contains(&spot), "spot={spot}");
}
