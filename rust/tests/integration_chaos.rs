//! Campaign-scale adversarial harness for the seeded fault model
//! (DESIGN.md §14). Four headline properties:
//!
//! 1. **Cache soundness under partial failure** — a run interrupted
//!    mid-pipeline never leaves state a later identical run could warm-hit
//!    from, and a fleet-wide stack-update day invalidates every cached
//!    execution on every affected machine at once.
//! 2. **Gates under correlated shifts** — the regression gate flags a
//!    planted fleet-wide stack regression on its exact day on every
//!    machine, while the unchanged control stays green (per-app noise is
//!    not enough to trip it).
//! 3. **Maturity under flakiness** — an app the fault plan makes flaky
//!    (forced node-failure window, source untouched) demotes on the exact
//!    day its windowed evidence decays, and re-earns its level on
//!    schedule once the window closes.
//! 4. **Determinism under chaos** — a 30-day armed chaos campaign (node
//!    failures, preemption + requeue, a scheduler outage, a maintenance
//!    drain, a stack-update day) replays byte-identically across replays,
//!    under `drive` vs `drive_reference`, and under seeded
//!    submission-order permutations; and the all-zero-rate plan is
//!    byte-identical to never arming anything.

use exacb::ci::Trigger;
use exacb::cluster::EventLog;
use exacb::coordinator::{collection, event_loop, BenchmarkRepo, World};
use exacb::scheduler::{FaultKind, FaultPlan, ForcedFault, JobState, Window};
use exacb::tracking;
use exacb::util::timeutil::SimTime;
use exacb::workloads::chaos::{self, ChaosScenario};
use exacb::workloads::portfolio;
use exacb::workloads::regression::RegressionScenario;

/// Every `sacct` field of every job on every machine, in jobid order.
fn sacct_dump(world: &World) -> String {
    let mut out = String::new();
    for (name, bs) in &world.batch {
        for r in bs.records_iter() {
            out.push_str(&format!(
                "{name} {} {} {:?} {:?} {:?} {} {} {:?}\n",
                r.jobid,
                r.state.name(),
                r.submit_time,
                r.start_time,
                r.end_time,
                r.spec.partition,
                r.spec.nodes,
                r.result
                    .as_ref()
                    .map(|res| (res.success, res.duration_s)),
            ));
        }
    }
    out
}

/// Every file on every branch of every repository store.
fn store_dump(world: &World) -> String {
    let mut out = String::new();
    for (name, repo) in &world.repos {
        let mut branches = repo.store.branches();
        branches.sort_unstable();
        for branch in branches {
            for (path, content) in repo.store.read_all(branch, "") {
                out.push_str(&format!("{name} {branch} {path} {}\n", content.len()));
                out.push_str(&content);
                out.push('\n');
            }
        }
    }
    out
}

fn fault_records(world: &World) -> usize {
    world
        .batch
        .values()
        .flat_map(|b| b.records_iter())
        .filter(|r| matches!(r.state, JobState::NodeFail | JobState::Preempted))
        .count()
}

// ---- 1. cache soundness under partial failure -------------------------

/// Satellite pin: a pipeline interrupted while its execute job is still
/// in flight must leave nothing a later identical run could warm-hit
/// from — run- and step-level cache entries are written only after a
/// successful collect, never at submission.
#[test]
fn interrupted_execution_never_warm_hits() {
    let mut world = World::new(41);
    world.enable_cache();
    world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
    world.advance_to(SimTime::from_days(0).add_secs(3 * 3600));

    // start a pipeline and abandon it at its first await: the execute
    // job is submitted (and will even complete inside the scheduler),
    // but the step is never collected
    let mut task = world.begin_pipeline("logmap", Trigger::Manual).unwrap();
    match task.poll(&mut world, None) {
        event_loop::TaskPoll::Waiting { .. } => {}
        other => panic!("expected the pipeline to block on its execute job, got {other:?}"),
    }
    drop(task); // interruption: the run dies mid-pipeline
    world.batch.get_mut("jedi").unwrap().run_until_idle();
    let stats = world.cache_stats();
    assert_eq!(
        stats.inserts, 0,
        "an uncollected execution must not have written cache entries"
    );
    let jobs_before = world.batch.get("jedi").unwrap().records().len();
    assert!(jobs_before > 0, "the interrupted run submitted its job");

    // an identical fresh run must be a cold miss — it re-executes
    world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
    let pid = world.run_pipeline("logmap", Trigger::Manual).unwrap();
    assert!(world.pipeline(pid).unwrap().succeeded());
    let stats = world.cache_stats();
    assert_eq!(
        stats.hits, 0,
        "fresh run warm-hit state recorded by an interrupted pipeline"
    );
    assert!(
        world.batch.get("jedi").unwrap().records().len() > jobs_before,
        "fresh run must re-submit, not replay"
    );

    // sanity: caching itself works — a *completed* run is replayable
    let pid = world.run_pipeline("logmap", Trigger::Manual).unwrap();
    assert!(world.pipeline(pid).unwrap().succeeded());
    assert!(
        world.cache_stats().hits > 0,
        "completed runs must warm-hit (otherwise the miss above proves nothing)"
    );
}

/// A stack-update day shifts the environment fingerprint of every
/// machine at once: every cached execution on every affected app is
/// invalidated in the same campaign day — no stale replay against the
/// new stack.
#[test]
fn stack_update_invalidates_caches_fleet_wide() {
    let machines = ["jedi", "jupiter"];
    let apps = portfolio::generate(2, 51);
    let mut world = World::new(51);
    world.enable_cache();
    let assignments = collection::onboard_multi(&mut world, &apps, &machines, "all");
    assert_eq!(assignments.len(), 2);

    let run_day = |world: &mut World, day: i64| {
        world.advance_to(SimTime::from_days(day).add_secs(3 * 3600));
        for (app, _) in &assignments {
            let pid = world.run_pipeline(app, Trigger::Scheduled).unwrap();
            assert!(world.pipeline(pid).unwrap().succeeded(), "{app} day {day}");
        }
    };
    let jobs_total =
        |world: &World| -> usize { world.batch.values().map(|b| b.records().len()).sum() };

    run_day(&mut world, 0); // cold
    let cold_jobs = jobs_total(&world);
    assert!(cold_jobs > 0);

    run_day(&mut world, 1); // warm: unchanged inputs replay everywhere
    let warm_hits = world.cache_stats().hits;
    assert!(warm_hits > 0, "day 1 must replay from cache");
    assert_eq!(jobs_total(&world), cold_jobs, "warm day must not submit");

    // day 2: the stack updates fleet-wide — every machine, every class
    for ev in EventLog::stack_update(&machines, 2, 0.85) {
        world.cluster.events.push(ev);
    }
    run_day(&mut world, 2);
    assert_eq!(
        world.cache_stats().hits,
        warm_hits,
        "no execution may warm-hit across a stack update"
    );
    let jobs_after = jobs_total(&world);
    assert!(
        jobs_after > cold_jobs,
        "the updated stack must re-execute, not replay"
    );
    // every machine re-executed: the invalidation is fleet-wide, not
    // per-machine best-effort
    for m in machines {
        let count = world.batch.get(m).unwrap().records().len();
        assert!(count > 0, "{m} never ran");
    }
    assert!(world.cache_stats().invalidated > 0, "keys must invalidate in place");
}

// ---- 2. gates vs a correlated fleet-wide shift ------------------------

/// The regression gate must flag a planted fleet-wide stack regression
/// on its exact day — on every machine the stack touched — while the
/// same campaign without the event stays green. Per-app noise alone
/// never trips the gate; the correlated baseline move does.
#[test]
fn gates_distinguish_stack_regression_from_noise() {
    let days = 12;
    let update_day = 6;
    for machine in ["jedi", "jupiter"] {
        let sc = RegressionScenario::control(machine, days, 271828);

        // control: unchanged source, unchanged stack — must stay green
        let mut clean_world = World::new(sc.seed);
        let clean = tracking::run_scenario(&mut clean_world, &sc);
        assert!(
            clean.failed_days.is_empty(),
            "{machine}: control campaign failed on {:?}",
            clean.failed_days
        );

        // same campaign, but the fleet's stack shifts on day 6
        let mut shifted_world = World::new(sc.seed);
        for ev in EventLog::stack_update(&["jedi", "jupiter"], update_day, 0.85) {
            shifted_world.cluster.events.push(ev);
        }
        let shifted = tracking::run_scenario(&mut shifted_world, &sc);
        assert!(
            shifted.failed_days.contains(&update_day),
            "{machine}: stack regression not caught on day {update_day}: {:?}",
            shifted.failed_days
        );
        assert!(
            shifted.failed_days.iter().all(|d| *d >= update_day),
            "{machine}: failure before the stack moved: {:?}",
            shifted.failed_days
        );
        assert_eq!(
            shifted.verdict_on(update_day),
            Some("regression"),
            "{machine}: gate verdict on the update day"
        );
    }
}

// ---- 3. maturity under fault-plan flakiness ---------------------------

/// An app whose *source never changes* but which the fault plan strikes
/// with a forced node-failure window demotes exactly when its windowed
/// evidence decays (`break + window_days - min_runs`), and re-earns its
/// level on schedule once the window closes — the same arithmetic as a
/// source-level breakage, driven entirely by the scheduler fault model.
#[test]
fn fault_flaky_app_demotes_on_the_maturity_schedule() {
    use exacb::workloads::onboarding::{OnboardingApp, OnboardingScenario};
    use exacb::workloads::portfolio::{Maturity, PortfolioApp};
    use exacb::workloads::scalable::AppModel;

    let fault_from = 5;
    let fault_until = 9; // window [5, 9): struck on days 5..=8
    let sc = OnboardingScenario {
        apps: vec![OnboardingApp {
            app: PortfolioApp {
                name: "fault-flaky".to_string(),
                domain: "cfd".to_string(),
                maturity: Maturity::Instrumentability,
                model: AppModel {
                    name: "fault-flaky".to_string(),
                    gflops_total: 20_000.0,
                    steps: 10,
                    ..AppModel::default()
                },
                failure_rate: 0.0,
                nodes: 1,
            },
            declared: Maturity::Instrumentability,
            instrument_from: Some(0),
            verify_from: None,
            break_day: None, // the source is never touched
            fix_day: None,
        }],
        days: 13,
        machines: vec!["jupiter".to_string()],
        queue: "all".to_string(),
        seed: 314,
        verify_every: 4,
        min_runs: 3,
        min_instrumented: 3,
        window_days: 6,
    };
    let mut world = World::new(sc.seed);
    let mut plan = FaultPlan::quiet("jupiter");
    plan.forced.push(ForcedFault {
        name_contains: "fault-flaky".to_string(),
        window: Window::new(
            SimTime::from_days(fault_from),
            SimTime::from_days(fault_until),
        ),
        kind: FaultKind::NodeFail,
    });
    world
        .batch
        .get_mut("jupiter")
        .unwrap()
        .set_fault_plan(Some(plan));

    let out = exacb::maturity::run_onboarding(&mut world, &sc);

    // before the window the app is healthy: no pipeline fails
    assert!(
        out.records
            .iter()
            .filter(|r| r.day < fault_from)
            .all(|r| r.pipeline_ok),
        "pipeline failed before the fault window opened"
    );
    // inside the window every run node-fails (retries are struck too)
    assert!(
        out.records
            .iter()
            .filter(|r| (fault_from..fault_until).contains(&r.day))
            .all(|r| !r.pipeline_ok),
        "a struck day still passed"
    );
    let node_fails = world
        .batch
        .get("jupiter")
        .unwrap()
        .records_iter()
        .filter(|r| r.state == JobState::NodeFail)
        .count();
    assert!(
        node_fails >= (fault_until - fault_from) as usize,
        "forced window produced only {node_fails} node failures"
    );

    // demotion lands exactly when windowed evidence decays: day
    // 5 + 6 - 3 = 8 — the same schedule a source breakage follows
    let demote_day = fault_from + sc.window_days as i64 - sc.min_runs as i64;
    assert_eq!(
        out.transition_day("fault-flaky", Maturity::Runnability),
        Some(demote_day),
        "{:?}",
        out.transitions_of("fault-flaky")
    );
    // and the level is re-earned on schedule after the window closes:
    // day 9 + 3 - 1 = 11
    let reearn_day = fault_until + sc.min_runs as i64 - 1;
    let transitions = out.transitions_of("fault-flaky");
    let reearn = transitions
        .iter()
        .find(|t| t.day > demote_day && t.to == Maturity::Instrumentability)
        .unwrap_or_else(|| panic!("no re-promotion: {transitions:?}"));
    assert_eq!(reearn.day, reearn_day);
}

// ---- 4. determinism under chaos ---------------------------------------

fn run_chaos(
    scenario: &ChaosScenario,
    world_seed: u64,
    drive: fn(&mut World, Vec<event_loop::PipelineTask>) -> Vec<u64>,
) -> (String, String, usize, usize, usize) {
    let mut world = World::new(world_seed);
    let summary = chaos::run_chaos_campaign_with(&mut world, scenario, drive);
    (
        sacct_dump(&world),
        store_dump(&world),
        summary.pipelines_run,
        summary.pipelines_succeeded,
        fault_records(&world),
    )
}

/// Headline: the 30-day armed chaos campaign — node failures,
/// preemption + requeue, one scheduler outage, one maintenance drain,
/// one fleet-wide stack-update day, one forced-flaky week — replays
/// byte-identically across replays, across `drive` vs `drive_reference`,
/// and across seeded submission-order permutations.
#[test]
fn chaos_campaign_replays_byte_identical() {
    for seed in [11u64, 97] {
        let sc = ChaosScenario::generate(3, 30, seed);
        let fast = run_chaos(&sc, seed, event_loop::drive);
        let replay = run_chaos(&sc, seed, event_loop::drive);
        let reference = run_chaos(&sc, seed, event_loop::drive_reference);

        // the campaign actually suffered: pipelines ran daily, some
        // faults struck, every pipeline was recorded (never dropped)
        assert_eq!(fast.2, 90, "3 apps x 30 days (seed {seed})");
        assert!(fast.4 > 0, "armed campaign never faulted (seed {seed})");
        assert!(
            fast.3 < fast.2,
            "the forced-flaky week must fail some pipelines (seed {seed})"
        );

        assert_eq!(fast, replay, "chaos replay diverged (seed {seed})");
        assert_eq!(
            fast, reference,
            "drive vs drive_reference diverged under chaos (seed {seed})"
        );
    }
}

/// Acceptance contract: arming the all-zero-rate fault plan (and its
/// empty event set) is byte-identical to never arming anything — the
/// fault model is pay-for-what-you-plant.
#[test]
fn zero_rate_fault_plan_is_byte_inert() {
    let seed = 2026;
    let sc = ChaosScenario::quiet(3, 10, seed);

    let armed = run_chaos(&sc, seed, event_loop::drive);

    // baseline: identical campaign, fault model never armed at all
    let machines: Vec<&str> = sc.machines.iter().map(String::as_str).collect();
    let mut world = World::new(seed);
    collection::onboard_multi(&mut world, &sc.apps, &machines, "all");
    let summary =
        collection::run_campaign_concurrent_with(&mut world, &sc.apps, &machines, sc.days, event_loop::drive);
    let baseline = (
        sacct_dump(&world),
        store_dump(&world),
        summary.pipelines_run,
        summary.pipelines_succeeded,
        fault_records(&world),
    );

    assert_eq!(armed.4, 0, "a zero-rate plan must never fault");
    assert_eq!(armed, baseline, "arming the quiet plan changed recorded bytes");
}
