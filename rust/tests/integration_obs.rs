//! Determinism contract of the observability layer (DESIGN.md §13).
//!
//! Three properties over a 24-app × 3-machine concurrent campaign:
//!
//! 1. An armed trace is **byte-identical** across two replays of the
//!    same seed — every event is stamped with sim-time and
//!    content-derived ids, never wall clock.
//! 2. The trace and metrics are identical whether the indexed
//!    dispatcher (`event_loop::drive`) or the frozen reference scan
//!    (`drive_reference`) drove the campaign — emission interleaving is
//!    normalized away by canonical content ordering.
//! 3. Arming the recorders is **invisible to the simulation**: the
//!    recorded reports, `sacct` records, and store bytes of an armed
//!    run match a disarmed run bit for bit.

use exacb::coordinator::{collection, event_loop, World};
use exacb::workloads::portfolio;

/// Every `sacct` field of every job on every machine, in jobid order.
fn sacct_dump(world: &World) -> String {
    let mut out = String::new();
    for (name, bs) in &world.batch {
        for r in bs.records_iter() {
            out.push_str(&format!(
                "{name} {} {} {:?} {:?} {:?} {} {} {:?}\n",
                r.jobid,
                r.state.name(),
                r.submit_time,
                r.start_time,
                r.end_time,
                r.spec.partition,
                r.spec.nodes,
                r.result
                    .as_ref()
                    .map(|res| (res.success, res.duration_s)),
            ));
        }
    }
    out
}

/// Every file on every branch of every repository store.
fn store_dump(world: &World) -> String {
    let mut out = String::new();
    for (name, repo) in &world.repos {
        let mut branches = repo.store.branches();
        branches.sort_unstable();
        for branch in branches {
            for (path, content) in repo.store.read_all(branch, "") {
                out.push_str(&format!("{name} {branch} {path} {}\n", content.len()));
                out.push_str(&content);
                out.push('\n');
            }
        }
    }
    out
}

/// Run the campaign with the recorders armed (or not) and return the
/// rendered trace JSON, the metrics sidecar JSON, and the simulation's
/// own recorded state.
fn run_observed(
    seed: u64,
    drive: fn(&mut World, Vec<event_loop::PipelineTask>) -> Vec<u64>,
    armed: bool,
) -> (String, String, String, String) {
    let apps = portfolio::generate(24, seed);
    let machines = ["jedi", "jupiter", "jureca"];
    let mut world = World::new(seed);
    collection::onboard_multi(&mut world, &apps, &machines, "all");
    // discard anything a previous test on this thread left behind
    exacb::obs::trace::drain();
    exacb::obs::metrics::drain();
    let prior_t = exacb::obs::set_tracing(armed);
    let prior_m = exacb::obs::set_metrics(armed);
    collection::run_campaign_concurrent_with(&mut world, &apps, &machines, 3, drive);
    exacb::obs::set_tracing(prior_t);
    exacb::obs::set_metrics(prior_m);
    let events = exacb::obs::trace::drain();
    let metrics = exacb::obs::metrics::drain();
    (
        exacb::obs::trace::chrome_trace_json(&events),
        metrics.to_json().pretty(),
        sacct_dump(&world),
        store_dump(&world),
    )
}

/// Property 1: replaying the same seed twice yields the same trace and
/// metrics bytes.
#[test]
fn armed_trace_is_byte_identical_across_replays() {
    let first = run_observed(2026, event_loop::drive, true);
    let second = run_observed(2026, event_loop::drive, true);
    assert!(!first.0.is_empty());
    assert_eq!(first.0, second.0, "trace bytes diverged across replays");
    assert_eq!(first.1, second.1, "metrics bytes diverged across replays");
}

/// Property 2: the trace is a pure function of the campaign, not of the
/// dispatcher that drove it.
#[test]
fn trace_is_identical_under_reference_dispatch() {
    let fast = run_observed(2026, event_loop::drive, true);
    let reference = run_observed(2026, event_loop::drive_reference, true);
    assert_eq!(
        fast.0, reference.0,
        "trace diverged between drive and drive_reference"
    );
    assert_eq!(
        fast.1, reference.1,
        "metrics diverged between drive and drive_reference"
    );
}

/// Property 3: arming the recorders changes nothing the simulation
/// records about itself — report.json and every other store byte, and
/// the full sacct dump, match a disarmed run exactly.
#[test]
fn arming_does_not_change_simulation_state() {
    let armed = run_observed(2026, event_loop::drive, true);
    let disarmed = run_observed(2026, event_loop::drive, false);
    assert!(
        disarmed.0.contains("\"traceEvents\": []")
            || !disarmed.0.contains("\"ph\": \"X\""),
        "disarmed run recorded trace events"
    );
    assert_eq!(armed.2, disarmed.2, "sacct records changed under arming");
    assert_eq!(armed.3, disarmed.3, "store bytes changed under arming");
}

/// Sanity: the armed campaign actually exercises the span vocabulary —
/// queue waits, runs, wakes, pipeline retirements.
#[test]
fn armed_trace_covers_span_vocabulary() {
    let apps = portfolio::generate(24, 2026);
    let machines = ["jedi", "jupiter", "jureca"];
    let mut world = World::new(2026);
    collection::onboard_multi(&mut world, &apps, &machines, "all");
    exacb::obs::trace::drain();
    exacb::obs::metrics::drain();
    let prior_t = exacb::obs::set_tracing(true);
    let prior_m = exacb::obs::set_metrics(true);
    collection::run_campaign_concurrent_with(
        &mut world,
        &apps,
        &machines,
        3,
        event_loop::drive,
    );
    exacb::obs::set_tracing(prior_t);
    exacb::obs::set_metrics(prior_m);
    let events = exacb::obs::trace::drain();
    let metrics = exacb::obs::metrics::drain();
    for name in ["queue-wait", "run", "complete", "wake", "retire", "day-trigger"] {
        assert!(
            events.iter().any(|e| e.name == name),
            "no `{name}` event in armed campaign trace"
        );
    }
    assert!(metrics.counter(exacb::obs::Ctr::JobsStarted) > 0);
    assert!(metrics.counter(exacb::obs::Ctr::PipelinesRun) > 0);
    assert!(metrics.counter(exacb::obs::Ctr::TaskWakes) > 0);
    assert_eq!(
        metrics.counter(exacb::obs::Ctr::PipelinesRun),
        metrics.counter(exacb::obs::Ctr::PipelinesSucceeded)
            + metrics.counter(exacb::obs::Ctr::PipelinesFailed),
        "pipeline outcome counters do not partition PipelinesRun"
    );
}

// ---- chaos campaigns under observation (DESIGN.md §14) ----------------

/// The armed chaos campaign this file observes: 4 apps for 8 days with
/// the forced-flaky window, an outage on the 03:00 trigger, a
/// maintenance drain, a stack-update day, and a preemption rate high
/// enough that requeues occur. Everything is seeded, so each assertion
/// is deterministic.
fn chaos_scenario(seed: u64) -> exacb::workloads::chaos::ChaosScenario {
    let mut sc = exacb::workloads::chaos::ChaosScenario::generate(4, 8, seed);
    sc.preempt_rate = 0.5;
    sc
}

fn run_chaos_observed(
    seed: u64,
    drive: fn(&mut World, Vec<event_loop::PipelineTask>) -> Vec<u64>,
    armed: bool,
) -> (String, String, String, String) {
    let sc = chaos_scenario(seed);
    let mut world = World::new(seed);
    exacb::obs::trace::drain();
    exacb::obs::metrics::drain();
    let prior_t = exacb::obs::set_tracing(armed);
    let prior_m = exacb::obs::set_metrics(armed);
    exacb::workloads::chaos::run_chaos_campaign_with(&mut world, &sc, drive);
    exacb::obs::set_tracing(prior_t);
    exacb::obs::set_metrics(prior_m);
    let events = exacb::obs::trace::drain();
    let metrics = exacb::obs::metrics::drain();
    (
        exacb::obs::trace::chrome_trace_json(&events),
        metrics.to_json().pretty(),
        sacct_dump(&world),
        store_dump(&world),
    )
}

/// The armed chaos campaign emits the full fault vocabulary as
/// canonical instants — node failures, preemptions, requeues, the
/// outage rejection — and the fault counters agree that they happened.
#[test]
fn chaos_trace_covers_fault_vocabulary() {
    let sc = chaos_scenario(2026);
    let mut world = World::new(2026);
    exacb::obs::trace::drain();
    exacb::obs::metrics::drain();
    let prior_t = exacb::obs::set_tracing(true);
    let prior_m = exacb::obs::set_metrics(true);
    exacb::workloads::chaos::run_chaos_campaign_with(&mut world, &sc, event_loop::drive);
    exacb::obs::set_tracing(prior_t);
    exacb::obs::set_metrics(prior_m);
    let events = exacb::obs::trace::drain();
    let metrics = exacb::obs::metrics::drain();
    for name in ["node-fail", "preempt", "requeue", "outage"] {
        assert!(
            events.iter().any(|e| e.name == name),
            "no `{name}` instant in the armed chaos trace"
        );
    }
    assert!(metrics.counter(exacb::obs::Ctr::JobsNodeFailed) > 0);
    assert!(metrics.counter(exacb::obs::Ctr::JobsPreempted) > 0);
    assert_eq!(
        metrics.counter(exacb::obs::Ctr::JobsPreempted),
        metrics.counter(exacb::obs::Ctr::JobsRequeued),
        "every preemption must requeue exactly one twin"
    );
}

/// Chaos does not loosen the determinism contract: the armed trace and
/// metrics are byte-identical across replays and across `drive` vs
/// `drive_reference` — the maintenance drain and outage deferrals
/// included.
#[test]
fn chaos_trace_is_byte_identical_across_replays() {
    let first = run_chaos_observed(2026, event_loop::drive, true);
    let second = run_chaos_observed(2026, event_loop::drive, true);
    let reference = run_chaos_observed(2026, event_loop::drive_reference, true);
    assert!(!first.0.is_empty());
    assert_eq!(first.0, second.0, "chaos trace diverged across replays");
    assert_eq!(first.1, second.1, "chaos metrics diverged across replays");
    assert_eq!(first.0, reference.0, "chaos trace diverged under drive_reference");
    assert_eq!(first.1, reference.1, "chaos metrics diverged under drive_reference");
}

/// Arming the recorders changes no byte of a chaos campaign's recorded
/// state — faults, retries, deferrals and all.
#[test]
fn arming_does_not_change_chaos_simulation_state() {
    let armed = run_chaos_observed(2026, event_loop::drive, true);
    let disarmed = run_chaos_observed(2026, event_loop::drive, false);
    assert_eq!(armed.2, disarmed.2, "chaos sacct records changed under arming");
    assert_eq!(armed.3, disarmed.3, "chaos store bytes changed under arming");
}
