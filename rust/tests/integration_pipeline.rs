//! Integration: CI pipeline → scheduler → workloads → protocol → store,
//! including failure injection across layers.

use exacb::ci::{CiJobState, Trigger};
use exacb::coordinator::{BenchmarkRepo, World};
use exacb::protocol::Report;
use exacb::util::table::Table;
use exacb::util::timeutil::SimTime;

fn scaling_repo(machine: &str, queue: &str) -> BenchmarkRepo {
    let jube = "name: scal\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        values: [1, 2, 4, 8]\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name scal --flops 400000 --comm-mb 64 --steps 120\n";
    let ci = format!(
        r#"
include:
  - component: execution@v3
    inputs:
      prefix: "{machine}.scal"
      machine: "{machine}"
      queue: "{queue}"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
"#
    );
    BenchmarkRepo::new("scal")
        .with_file("b.yml", jube)
        .with_file(".gitlab-ci.yml", &ci)
}

#[test]
fn parameter_study_flows_to_table_and_store() {
    let mut world = World::new(1);
    world.add_repo(scaling_repo("jedi", "all"));
    let pid = world.run_pipeline("scal", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(p.succeeded());

    // results.csv has 4 rows with decreasing runtimes
    let csv = p
        .job("jedi.scal.execute")
        .unwrap()
        .artifact("results.csv")
        .unwrap();
    let t = Table::from_csv(csv).unwrap();
    assert_eq!(t.len(), 4);
    let runtimes: Vec<f64> = t
        .column("runtime")
        .unwrap()
        .iter()
        .map(|v| v.parse().unwrap())
        .collect();
    assert!(runtimes[3] < runtimes[0]);
    // jobids are distinct scheduler jobs
    let mut jobids = t.column("jobid").unwrap();
    jobids.dedup();
    assert_eq!(jobids.len(), 4);

    // protocol report on the branch parses and matches
    let repo = world.repo("scal").unwrap();
    let doc = repo
        .store
        .read("exacb.data", &format!("jedi.scal/{pid}/report.json"))
        .unwrap();
    let report = Report::parse(doc).unwrap();
    assert_eq!(report.data.len(), 4);
    assert_eq!(
        report.data.iter().map(|e| e.nodes).collect::<Vec<_>>(),
        vec![1, 2, 4, 8]
    );
}

#[test]
fn multi_machine_comparison_through_components() {
    // run the same benchmark on two systems, then post-process through
    // the machine-comparison component on a meta-repo.
    let mut world = World::new(2);
    for (m, q) in [("jedi", "all"), ("jureca", "dc-gpu")] {
        let mut repo = scaling_repo(m, q);
        repo.name = format!("scal-{m}");
        world.add_repo(repo);
        world
            .run_pipeline(&format!("scal-{m}"), Trigger::Manual)
            .unwrap();
    }
    // merge both stores into one meta-repo (the paper's cross-repo
    // comparison pulls from multiple exacb.data branches)
    let mut meta = BenchmarkRepo::new("meta");
    for m in ["jedi", "jureca"] {
        let src = world.repo(&format!("scal-{m}")).unwrap();
        let files = src.store.read_all("exacb.data", "");
        let files: Vec<(String, String)> = files;
        meta.store
            .commit("exacb.data", &files, "merge", SimTime(0));
    }
    let inputs = exacb::util::json::Json::obj()
        .set("prefix", "evaluation.jedi")
        .set("selector", vec!["jedi.scal", "jureca.scal"]);
    let job = {
        let resolved = world
            .registry
            .get("machine-comparison@v3")
            .unwrap()
            .resolve(&inputs)
            .unwrap();
        exacb::coordinator::postproc::run_machine_comparison(&mut world, &meta, &resolved)
    };
    assert_eq!(job.state, CiJobState::Success, "{:?}", job.log);
    let csv = Table::from_csv(job.artifact("comparison.csv").unwrap()).unwrap();
    // both systems, 4 node counts each
    assert_eq!(csv.len(), 8);
    let svg = job.artifact("comparison.svg").unwrap();
    assert!(svg.contains("jureca (/2)")); // Ampere halved, as in Fig. 5
}

#[test]
fn runner_failure_fails_setup_but_leaves_no_partial_data() {
    let mut world = World::new(3);
    world.add_repo(scaling_repo("jedi", "ghost-queue"));
    let pid = world.run_pipeline("scal", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(!p.succeeded());
    assert_eq!(p.jobs.len(), 1);
    assert_eq!(p.jobs[0].state, CiJobState::Failed);
    // nothing recorded
    let repo = world.repo("scal").unwrap();
    assert!(!repo.store.branch_exists("exacb.data"));
}

#[test]
fn budget_exhaustion_mid_campaign() {
    let mut world = World::new(4);
    // tight budget: the first pipeline (~34 core-hours across its 4
    // scaling jobs) fits, the second exhausts mid-study
    world
        .batch
        .get_mut("jedi")
        .unwrap()
        .accounts
        .add_budget("zam", 40.0); // overwrite with 40 core-hours
    world.add_repo(scaling_repo("jedi", "all"));
    let first = world.run_pipeline("scal", Trigger::Scheduled).unwrap();
    assert!(world.pipeline(first).unwrap().succeeded());
    // consume: the first run already charged > 10 core-hours
    let second = world.run_pipeline("scal", Trigger::Scheduled).unwrap();
    let p2 = world.pipeline(second).unwrap();
    assert!(!p2.succeeded(), "second run must fail on exhausted budget");
    assert!(p2.jobs[0].log[0].contains("exhausted"), "{:?}", p2.jobs[0].log);
}

#[test]
fn crashed_application_marks_failed_but_still_records() {
    let mut world = World::new(5);
    let jube = "name: crashy\nsteps:\n  - name: execute\n    remote: true\n    do:\n      - nonexistent-binary --x\n";
    let ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jedi.crashy"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
"#;
    world.add_repo(
        BenchmarkRepo::new("crashy")
            .with_file("b.yml", jube)
            .with_file(".gitlab-ci.yml", ci),
    );
    let pid = world.run_pipeline("crashy", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(!p.succeeded());
    // execute failed but record still happened ("robust against partial
    // or incremental data generation"): the report carries success=false
    let repo = world.repo("crashy").unwrap();
    let doc = repo
        .store
        .read("exacb.data", &format!("jedi.crashy/{pid}/report.json"))
        .unwrap();
    let report = Report::parse(doc).unwrap();
    assert_eq!(report.data.len(), 1);
    assert!(!report.data[0].success);
}

#[test]
fn daily_schedule_advances_sim_clock_not_host_clock() {
    let mut world = World::new(6);
    world.add_repo(scaling_repo("jedi", "all"));
    let host_start = std::time::Instant::now();
    for d in 0..30 {
        world.advance_to(SimTime::from_days(d).add_secs(3 * 3600));
        world.run_pipeline("scal", Trigger::Scheduled).unwrap();
    }
    // 30 simulated days in a few host seconds
    assert!(world.now() >= SimTime::from_days(29));
    assert!(host_start.elapsed().as_secs() < 60);
    // 30 reports accumulated on the branch, all retrievable a-posteriori
    let repo = world.repo("scal").unwrap();
    assert_eq!(repo.store.history("exacb.data").len(), 30);
    let (set, _) =
        exacb::analysis::ReportSet::load(&repo.store, "exacb.data", "jedi.scal/");
    assert_eq!(set.len(), 30);
}

#[test]
fn cross_trigger_between_repositories() {
    // §IV-C: "coordinated execution of benchmarks across multiple
    // repositories through cross-triggered CI pipelines"
    let mut world = World::new(8);
    world.add_repo(scaling_repo("jedi", "all"));
    let mut repo2 = scaling_repo("jureca", "dc-gpu");
    repo2.name = "scal2".into();
    world.add_repo(repo2);
    let p1 = world.run_pipeline("scal", Trigger::Manual).unwrap();
    let p2 = world
        .run_pipeline("scal2", Trigger::Cross { from_pipeline: p1 })
        .unwrap();
    assert!(world.pipeline(p2).unwrap().succeeded());
    assert_eq!(
        world.pipeline(p2).unwrap().trigger,
        Trigger::Cross { from_pipeline: p1 }
    );
}
