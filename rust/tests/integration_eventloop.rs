//! Integration tests for the discrete-event execution core: the
//! equivalence contract between the legacy sequential dispatch and the
//! concurrent event loop, and the contention scenarios the sequential
//! path could never express (ISSUE 2 acceptance criteria).

use exacb::coordinator::{collection, postproc, World};
use exacb::prop_assert;
use exacb::util::prop::{check, Gen};

/// Satellite: property test — on a single machine, an event-free
/// campaign produces byte-identical `collection_results_table` output
/// whether pipelines are dispatched sequentially (legacy
/// `run_campaign_queued`) or interleaved by the event loop
/// (`run_campaign_concurrent`), for any seed and portfolio size. The
/// per-item PRNG streams and day-granular aggregation make results
/// independent of the timeline interleaving.
#[test]
fn prop_event_loop_equals_sequential_dispatch_single_machine() {
    check("event loop == sequential on one machine", 6, |g: &mut Gen| {
        let seed = g.u64(1, 1_000_000);
        let n_apps = g.usize(2, 6);
        let days = g.i64(1, 2);
        let apps = exacb::workloads::portfolio::generate(n_apps, seed);
        let machines = ["jedi"];

        let mut seq = World::new(seed);
        collection::onboard_multi(&mut seq, &apps, &machines, "all");
        let s1 = collection::run_campaign_queued(&mut seq, &apps, &machines, days);

        let mut con = World::new(seed);
        collection::onboard_multi(&mut con, &apps, &machines, "all");
        let s2 = collection::run_campaign_concurrent(&mut con, &apps, &machines, days);

        prop_assert!(
            s1.pipelines_run == s2.pipelines_run
                && s1.pipelines_succeeded == s2.pipelines_succeeded,
            "pipeline counts diverged: seq {}/{} vs con {}/{}",
            s1.pipelines_succeeded,
            s1.pipelines_run,
            s2.pipelines_succeeded,
            s2.pipelines_run
        );
        for metric in ["runtime", "tts"] {
            let t1 = postproc::collection_results_table(&seq, metric).to_csv();
            let t2 = postproc::collection_results_table(&con, metric).to_csv();
            prop_assert!(
                t1 == t2,
                "{metric} table diverged (seed {seed}, {n_apps} apps, {days} days)"
            );
        }
        Ok(())
    });
}

/// Acceptance: a 24-app × 3-machine concurrent campaign produces
/// *nonzero* queue waits on shared partitions — jobs actually wait for
/// nodes held by other applications, beyond the fixed scheduler-cycle
/// latency. The sequential dispatcher drains every pipeline before the
/// next starts, so it can never show a wait above the latency floor.
#[test]
fn concurrent_campaign_contends_on_shared_partitions() {
    let mut apps = exacb::workloads::portfolio::generate(24, 42);
    for app in &mut apps {
        app.failure_rate = 0.0;
        // pin geometry so the per-machine groups oversubscribe their
        // partition deterministically (8 apps x 8 nodes > jedi's 48)
        app.nodes = 8;
    }
    let machines = ["jedi", "jupiter", "jureca"];
    let mut world = World::new(42);
    collection::onboard_multi(&mut world, &apps, &machines, "all");
    let summary = collection::run_campaign_concurrent(&mut world, &apps, &machines, 1);
    assert_eq!(summary.pipelines_run, 24);
    assert_eq!(summary.pipelines_succeeded, 24);

    // every machine ran its share of the campaign
    for m in &machines {
        assert!(
            !world.batch.get(*m).unwrap().records().is_empty(),
            "{m} ran no jobs"
        );
    }
    // contention is real somewhere: at least one job waited beyond the
    // scheduler latency for nodes another application held
    let excess_waits: usize = world
        .batch
        .values()
        .map(|bs| {
            let latency = bs.sched_latency_s;
            bs.records()
                .iter()
                .filter_map(|r| r.queue_wait_s())
                .filter(|w| *w > latency)
                .count()
        })
        .sum();
    assert!(
        excess_waits > 0,
        "expected nonzero queue waits on shared partitions"
    );
    // and the observability satellite sees it: queue_stats reports a
    // p95 above the latency floor for the oversubscribed machine
    let stats = postproc::queue_stats(&world);
    let jedi_row = stats
        .rows
        .iter()
        .find(|r| r[0] == "jedi")
        .expect("jedi ran jobs");
    let latency = world.batch.get("jedi").unwrap().sched_latency_s;
    let p95: f64 = jedi_row[3].parse().unwrap();
    assert!(
        p95 > latency as f64,
        "jedi p95 wait {p95}s should exceed the {latency}s latency floor"
    );
}

/// The warm-sweep cache contract survives the event core: a concurrent
/// repeat sweep over unchanged inputs replays every pipeline from the
/// execution cache with zero new batch submissions.
#[test]
fn concurrent_warm_sweep_submits_zero_jobs() {
    let mut apps = exacb::workloads::portfolio::generate(6, 51);
    for app in &mut apps {
        app.failure_rate = 0.0;
    }
    let machines = ["jedi", "jupiter"];
    let mut world = World::new(51);
    world.enable_cache();
    collection::onboard_multi(&mut world, &apps, &machines, "all");
    let cold = collection::run_campaign_concurrent(&mut world, &apps, &machines, 1);
    let jobs_cold: usize = world.batch.values().map(|b| b.records().len()).sum();
    assert!(jobs_cold > 0);
    assert!(cold.cache.misses > 0);
    let warm = collection::run_campaign_concurrent(&mut world, &apps, &machines, 1);
    let jobs_total: usize = world.batch.values().map(|b| b.records().len()).sum();
    assert_eq!(
        jobs_total, jobs_cold,
        "warm concurrent sweep must submit zero batch jobs"
    );
    assert_eq!(warm.pipelines_succeeded, warm.pipelines_run);
    assert!(warm.cache.hits > cold.cache.hits);
    assert_eq!(warm.cache.misses, cold.cache.misses);
}
