//! Integration: the incremental execution cache across the whole stack —
//! CI pipeline → run/step cache → scheduler → protocol → store.
//!
//! Covers the paper's namesake claim end to end: unchanged inputs replay
//! with zero batch submissions and byte-identical recorded reports;
//! mutating exactly one input (definition, parameter value, software
//! stage, injected feature) re-executes exactly the affected steps.

use exacb::ci::Trigger;
use exacb::coordinator::{collection, postproc, BenchmarkRepo, World};
use exacb::protocol::CacheOutcome;
use exacb::workloads::portfolio;

/// A two-remote-step benchmark: `prepare` does not consume the `run`
/// parameter set, `execute` does — so parameter mutations must re-run
/// `execute` only.
fn granular_repo(steps_value: u64) -> BenchmarkRepo {
    let jube = format!(
        r#"name: gran
parametersets:
  - name: run
    parameters:
      - name: steps
        value: {steps_value}
steps:
  - name: prepare
    remote: true
    do:
      - simapp --name prep --flops 50000 --steps 10
  - name: execute
    depends: [prepare]
    use: [run]
    remote: true
    do:
      - simapp --name gran --flops 200000 --steps $steps
"#
    );
    let ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jedi.gran"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
"#;
    BenchmarkRepo::new("gran")
        .with_file("b.yml", &jube)
        .with_file(".gitlab-ci.yml", ci)
}

fn patch_repo_file(world: &mut World, repo: &str, path: &str, content: &str) {
    let r = world.repos.get_mut(repo).unwrap();
    for (p, c) in r.files.iter_mut() {
        if p == path {
            *c = content.to_string();
        }
    }
}

#[test]
fn same_inputs_sweep_is_pure_replay() {
    let mut world = World::new(31);
    world.enable_cache();
    world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));

    let p1 = world.run_pipeline("logmap", Trigger::Manual).unwrap();
    let jobs_cold = world.batch.get("jedi").unwrap().records().len();
    assert!(jobs_cold > 0);

    let p2 = world.run_pipeline("logmap", Trigger::Manual).unwrap();
    let warm = world.pipeline(p2).unwrap().clone();
    assert!(warm.succeeded());
    // 100% hit, zero new submissions
    let (h, m, i) = warm.cache_summary();
    assert!(h >= 1);
    assert_eq!((m, i), (0, 0));
    assert_eq!(world.batch.get("jedi").unwrap().records().len(), jobs_cold);

    // byte-identical report.json and results.csv on the data branch
    let repo = world.repo("logmap").unwrap();
    for file in ["report.json", "results.csv"] {
        let cold = repo
            .store
            .read("exacb.data", &format!("jedi.logmap/{p1}/{file}"))
            .unwrap();
        let warm_doc = repo
            .store
            .read("exacb.data", &format!("jedi.logmap/{p2}/{file}"))
            .unwrap();
        assert_eq!(cold, warm_doc, "{file} must replay byte-identically");
    }

    // the warm execute job carries an all-hit cache.json artifact
    let execute = warm.job("jedi.logmap.execute").unwrap();
    let prov = exacb::protocol::parse_provenance(execute.artifact("cache.json").unwrap());
    assert!(!prov.is_empty());
    assert!(prov.iter().all(|s| s.status == CacheOutcome::Hit));
}

#[test]
fn parameter_mutation_invalidates_only_affected_steps() {
    let mut world = World::new(32);
    world.enable_cache();
    world.add_repo(granular_repo(20));

    world.run_pipeline("gran", Trigger::Manual).unwrap();
    let jobs_cold = world.batch.get("jedi").unwrap().records().len();
    assert_eq!(jobs_cold, 2); // prepare + execute

    // mutate the parameter value consumed by `execute` only
    let mutated = granular_repo(40);
    patch_repo_file(&mut world, "gran", "b.yml", mutated.file("b.yml").unwrap());

    let pid = world.run_pipeline("gran", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(p.succeeded());
    // exactly one new batch job: `execute` re-ran, `prepare` replayed
    assert_eq!(world.batch.get("jedi").unwrap().records().len(), jobs_cold + 1);
    let execute = p.job("jedi.gran.execute").unwrap();
    let by_step = |name: &str| {
        execute
            .provenance
            .iter()
            .find(|s| s.step == name)
            .unwrap_or_else(|| panic!("no provenance for {name}"))
            .status
    };
    assert_eq!(by_step("prepare"), CacheOutcome::Hit);
    assert_eq!(by_step("execute"), CacheOutcome::Invalidated);
}

#[test]
fn definition_mutation_invalidates_only_affected_steps() {
    let mut world = World::new(33);
    world.enable_cache();
    world.add_repo(granular_repo(20));
    world.run_pipeline("gran", Trigger::Manual).unwrap();
    let jobs_cold = world.batch.get("jedi").unwrap().records().len();

    // edit the `prepare` command line (a JUBE definition change)
    let edited = granular_repo(20)
        .file("b.yml")
        .unwrap()
        .replace("--flops 50000", "--flops 60000");
    patch_repo_file(&mut world, "gran", "b.yml", &edited);

    let pid = world.run_pipeline("gran", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(p.succeeded());
    assert_eq!(world.batch.get("jedi").unwrap().records().len(), jobs_cold + 1);
    let execute = p.job("jedi.gran.execute").unwrap();
    let statuses: Vec<(String, CacheOutcome)> = execute
        .provenance
        .iter()
        .map(|s| (s.step.clone(), s.status))
        .collect();
    assert!(
        statuses.contains(&("prepare".into(), CacheOutcome::Invalidated)),
        "{statuses:?}"
    );
    assert!(
        statuses.contains(&("execute".into(), CacheOutcome::Hit)),
        "{statuses:?}"
    );
}

#[test]
fn stage_mutation_invalidates_every_remote_step() {
    let mut world = World::new(34);
    world.enable_cache();
    world.add_repo(granular_repo(20));
    world.run_pipeline("gran", Trigger::Manual).unwrap();
    let jobs_cold = world.batch.get("jedi").unwrap().records().len();

    // switch the SoftwareStage in the CI inputs: environment fingerprint
    // changes, so every remote step must re-execute
    let ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jedi.gran"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
      stage: "2025"
"#;
    patch_repo_file(&mut world, "gran", ".gitlab-ci.yml", ci);

    let pid = world.run_pipeline("gran", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(p.succeeded());
    assert_eq!(
        world.batch.get("jedi").unwrap().records().len(),
        jobs_cold + 2,
        "both steps re-run under the 2025 stage"
    );
    let (h, m, i) = p.cache_summary();
    assert_eq!(h, 0, "no step may hit across stages (h={h} m={m} i={i})");
    assert_eq!(m + i, 2);
}

#[test]
fn injected_feature_mutation_invalidates_every_remote_step() {
    let mut world = World::new(35);
    world.enable_cache();
    world.add_repo(granular_repo(20));
    world.run_pipeline("gran", Trigger::Manual).unwrap();
    let jobs_cold = world.batch.get("jedi").unwrap().records().len();

    // same benchmark through the feature-injection component: the
    // injected command is prepended to every remote step
    let ci = r#"
include:
  - component: feature-injection@v3
    inputs:
      prefix: "jedi.gran"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
      in_command: "export UCX_RNDV_THRESH=intra:65536,inter:65536"
"#;
    patch_repo_file(&mut world, "gran", ".gitlab-ci.yml", ci);

    let pid = world.run_pipeline("gran", Trigger::Manual).unwrap();
    let p = world.pipeline(pid).unwrap();
    assert!(p.succeeded());
    assert_eq!(world.batch.get("jedi").unwrap().records().len(), jobs_cold + 2);
    let (h, _, _) = p.cache_summary();
    assert_eq!(h, 0, "injected features must not replay uninjected results");

    // and re-running the injected variant is itself a pure replay
    let pid2 = world.run_pipeline("gran", Trigger::Manual).unwrap();
    assert_eq!(world.batch.get("jedi").unwrap().records().len(), jobs_cold + 2);
    let (h2, m2, i2) = world.pipeline(pid2).unwrap().cache_summary();
    assert!(h2 >= 1);
    assert_eq!((m2, i2), (0, 0));
}

/// Satellite: two concurrent (work-queued) collection runs with the same
/// seed produce identical, order-independently aggregated report tables.
#[test]
fn concurrent_campaigns_same_seed_identical_tables() {
    let run = |seed: u64| {
        let apps = portfolio::generate(8, seed);
        let mut world = World::new(seed);
        let machines = ["jupiter", "jedi"];
        collection::onboard_multi(&mut world, &apps, &machines, "all");
        let summary = collection::run_campaign_queued(&mut world, &apps, &machines, 3);
        let table = postproc::collection_results_table(&world, "runtime");
        (summary, table.to_csv())
    };
    let (s1, t1) = run(4242);
    let (s2, t2) = run(4242);
    assert_eq!(t1, t2, "same seed must give byte-identical tables");
    assert_eq!(s1.pipelines_run, s2.pipelines_run);
    assert_eq!(s1.pipelines_succeeded, s2.pipelines_succeeded);
    assert_eq!(s1.core_hours, s2.core_hours);
    assert!(!t1.is_empty());

    // a different seed reorders dispatch and resamples noise
    let (_, t3) = run(4243);
    assert_ne!(t1, t3);
}

/// Satellite (the stronger form): the aggregated table is independent of
/// the dispatch *interleaving* itself, not just reproducible for one
/// seed — the same items dispatched in a completely different order
/// yield the byte-identical table, because each item's noise stream is
/// derived from (seed, day, app) rather than from dispatch position.
#[test]
fn aggregation_is_independent_of_dispatch_order() {
    let seed = 777;
    let apps = portfolio::generate(6, seed);
    let machines = ["jupiter", "jedi"];

    // run A: seed-shuffled round-robin work queue
    let mut wa = World::new(seed);
    collection::onboard_multi(&mut wa, &apps, &machines, "all");
    collection::run_campaign_queued(&mut wa, &apps, &machines, 2);
    let ta = postproc::collection_results_table(&wa, "runtime").to_csv();

    // run B: the same items in plain (day, app-index) order
    let mut wb = World::new(seed);
    collection::onboard_multi(&mut wb, &apps, &machines, "all");
    for day in 0..2 {
        for app in &apps {
            collection::dispatch_item(&mut wb, app, day);
        }
    }
    let tb = postproc::collection_results_table(&wb, "runtime").to_csv();

    assert_eq!(ta, tb, "aggregation must not depend on dispatch interleaving");
    assert!(!ta.is_empty());
}
