//! Perf: end-to-end pipeline latency and coordinator overhead
//! (DESIGN.md §8 target: coordinator overhead < 5% of pipeline wall).
//!
//! Measures (a) a full single-benchmark pipeline, (b) the same with the
//! workload model replaced by a no-op-cost app, isolating framework
//! overhead, (c) campaign throughput in pipelines/s, (d) the
//! incremental-execution contract: a warm (unchanged-input) collection
//! sweep submits **zero** batch jobs and is ≥5x faster than the cold
//! sweep (asserted, not just reported), and (e) campaign throughput in
//! pipelines per **simulated** day at 24 apps × 3 machines: the
//! discrete-event concurrent runner vs the sequential dispatcher
//! (concurrent must finish each day's batch in less simulated time —
//! asserted).

use exacb::bench::Bench;
use exacb::ci::Trigger;
use exacb::coordinator::{collection, BenchmarkRepo, World};
use exacb::workloads::portfolio;

fn repo(cmd: &str) -> BenchmarkRepo {
    let jube = format!(
        "name: app\nsteps:\n  - name: execute\n    remote: true\n    do:\n      - {cmd}\n"
    );
    let ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jedi.app"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
"#;
    BenchmarkRepo::new("app")
        .with_file("b.yml", &jube)
        .with_file(".gitlab-ci.yml", ci)
}

fn run_once(cmd: &str, seed: u64) -> std::time::Duration {
    let mut world = World::new(seed);
    world.add_repo(repo(cmd));
    let t0 = std::time::Instant::now();
    world.run_pipeline("app", Trigger::Manual).unwrap();
    t0.elapsed()
}

fn main() {
    let mut b = Bench::new();
    let mut seed = 0u64;
    b.case("pipeline: simapp workload", || {
        seed += 1;
        run_once("simapp --name x --flops 100000 --steps 100", seed)
    });
    b.case("pipeline: graph500 scale 12 (real BFS)", || {
        seed += 1;
        run_once("graph500 --scale 12 --nbfs 2", seed)
    });
    b.case("pipeline: trivial app (framework floor)", || {
        seed += 1;
        run_once("simapp --name x --flops 1 --steps 1", seed)
    });
    // campaign throughput (world reused, store grows)
    let mut world = World::new(99);
    world.add_repo(repo("simapp --name x --flops 100000 --steps 100"));
    let mut day = 0i64;
    b.throughput_case("scheduled pipelines (1/day)", 1.0, "pipelines", || {
        day += 1;
        world.advance_to(exacb::util::timeutil::SimTime::from_days(day));
        world.run_pipeline("app", Trigger::Scheduled).unwrap()
    });
    b.report("perf_e2e");

    let full = b.results()[0].mean.as_secs_f64();
    let floor = b.results()[2].mean.as_secs_f64();
    println!(
        "\nframework floor = {:.3} ms; full pipeline = {:.3} ms; overhead ratio = {:.1}%",
        floor * 1e3,
        full * 1e3,
        100.0 * floor / full
    );
    println!(
        "(the floor includes YAML parse + component validation + scheduler + store commit)"
    );

    // ---- incremental execution: cold vs warm collection sweep ---------
    let mut apps = portfolio::generate(12, 7);
    for app in &mut apps {
        app.failure_rate = 0.0; // flaky injection would change the inputs
    }
    let machines = ["jupiter", "jedi"];
    let mut world = World::new(7);
    world.enable_cache();
    collection::onboard_multi(&mut world, &apps, &machines, "all");

    let t0 = std::time::Instant::now();
    let cold_summary = collection::run_campaign_queued(&mut world, &apps, &machines, 1);
    let cold = t0.elapsed();
    let jobs_cold: usize = world.batch.values().map(|b| b.records().len()).sum();

    let t1 = std::time::Instant::now();
    let warm_summary = collection::run_campaign_queued(&mut world, &apps, &machines, 1);
    let warm = t1.elapsed();
    let jobs_total: usize = world.batch.values().map(|b| b.records().len()).sum();
    let jobs_warm = jobs_total - jobs_cold;
    let speedup = cold.as_secs_f64() / warm.as_secs_f64().max(1e-9);

    println!("\n== incremental collection sweep (12 apps x 2 machines, 1 day) ==");
    println!(
        "cold sweep: {:>10.3} ms  ({} batch jobs, {} pipelines ok)",
        cold.as_secs_f64() * 1e3,
        jobs_cold,
        cold_summary.pipelines_succeeded,
    );
    println!(
        "warm sweep: {:>10.3} ms  ({} batch jobs, {} cache hits, {} pipelines ok)",
        warm.as_secs_f64() * 1e3,
        jobs_warm,
        warm_summary.cache.hits - cold_summary.cache.hits,
        warm_summary.pipelines_succeeded,
    );
    println!("warm/cold speedup: {speedup:.1}x");
    assert_eq!(jobs_warm, 0, "warm sweep submitted batch jobs");
    assert!(
        speedup >= 5.0,
        "warm sweep must be >=5x faster than cold (got {speedup:.1}x)"
    );

    // ---- campaign throughput: concurrent event loop vs sequential -----
    // 24 apps x 3 machines, one simulated day. Throughput is pipelines
    // per simulated day of *drain time*: how long past the 03:00 trigger
    // the campaign keeps the machines busy. The sequential dispatcher
    // serializes every pipeline; the event loop overlaps them, limited
    // only by real node contention on the shared partitions.
    let mut apps = portfolio::generate(24, 61);
    for app in &mut apps {
        app.failure_rate = 0.0;
        // pin geometry so each machine's 8 apps oversubscribe jedi's
        // 48-node partition deterministically (8 x 8 > 48) — the
        // contention assertion below must not depend on random draws
        app.nodes = 8;
    }
    let machines = ["jedi", "jupiter", "jureca"];
    let trigger_s: i64 = 3 * 3600;

    let mut seq_world = World::new(61);
    collection::onboard_multi(&mut seq_world, &apps, &machines, "all");
    let t0 = std::time::Instant::now();
    let seq_sum = collection::run_campaign_queued(&mut seq_world, &apps, &machines, 1);
    let seq_wall = t0.elapsed();
    let seq_drain_s = (seq_world.now().0 - trigger_s).max(1);

    let mut con_world = World::new(61);
    collection::onboard_multi(&mut con_world, &apps, &machines, "all");
    let t1 = std::time::Instant::now();
    let con_sum = collection::run_campaign_concurrent(&mut con_world, &apps, &machines, 1);
    let con_wall = t1.elapsed();
    let con_drain_s = (con_world.now().0 - trigger_s).max(1);

    let per_day = |n: usize, drain_s: i64| n as f64 * 86_400.0 / drain_s as f64;
    println!("\n== campaign throughput (24 apps x 3 machines, 1 day) ==");
    println!(
        "sequential: {:>9.3} ms wall, {:>6} s simulated drain, {:>10.0} pipelines/sim-day ({} ok)",
        seq_wall.as_secs_f64() * 1e3,
        seq_drain_s,
        per_day(seq_sum.pipelines_run, seq_drain_s),
        seq_sum.pipelines_succeeded,
    );
    println!(
        "concurrent: {:>9.3} ms wall, {:>6} s simulated drain, {:>10.0} pipelines/sim-day ({} ok)",
        con_wall.as_secs_f64() * 1e3,
        con_drain_s,
        per_day(con_sum.pipelines_run, con_drain_s),
        con_sum.pipelines_succeeded,
    );
    println!(
        "simulated-makespan speedup: {:.1}x",
        seq_drain_s as f64 / con_drain_s as f64
    );
    assert_eq!(seq_sum.pipelines_succeeded, con_sum.pipelines_succeeded);
    assert!(
        con_drain_s < seq_drain_s,
        "concurrent campaign must finish the day in less simulated time \
         (sequential {seq_drain_s}s vs concurrent {con_drain_s}s)"
    );
    // contention is modelled, not serialized away: jedi's 48-node "all"
    // partition is shared by 8 pinned 8-node apps, so at least one job
    // must have waited beyond the fixed scheduler latency
    let excess_waits: usize = con_world
        .batch
        .values()
        .map(|bs| {
            let latency = bs.sched_latency_s;
            bs.records()
                .iter()
                .filter_map(|r| r.queue_wait_s())
                .filter(|w| *w > latency)
                .count()
        })
        .sum();
    println!("queue waits beyond scheduler latency: {excess_waits} jobs");
    assert!(
        excess_waits > 0,
        "concurrent campaign must produce real queue waits on shared partitions"
    );
}
