//! Perf: end-to-end pipeline latency and coordinator overhead
//! (DESIGN.md §8 target: coordinator overhead < 5% of pipeline wall).
//!
//! Measures (a) a full single-benchmark pipeline, (b) the same with the
//! workload model replaced by a no-op-cost app, isolating framework
//! overhead, and (c) campaign throughput in pipelines/s.

use exacb::bench::Bench;
use exacb::ci::Trigger;
use exacb::coordinator::{BenchmarkRepo, World};

fn repo(cmd: &str) -> BenchmarkRepo {
    let jube = format!(
        "name: app\nsteps:\n  - name: execute\n    remote: true\n    do:\n      - {cmd}\n"
    );
    let ci = r#"
include:
  - component: execution@v3
    inputs:
      prefix: "jedi.app"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "b.yml"
"#;
    BenchmarkRepo::new("app")
        .with_file("b.yml", &jube)
        .with_file(".gitlab-ci.yml", ci)
}

fn run_once(cmd: &str, seed: u64) -> std::time::Duration {
    let mut world = World::new(seed);
    world.add_repo(repo(cmd));
    let t0 = std::time::Instant::now();
    world.run_pipeline("app", Trigger::Manual).unwrap();
    t0.elapsed()
}

fn main() {
    let mut b = Bench::new();
    let mut seed = 0u64;
    b.case("pipeline: simapp workload", || {
        seed += 1;
        run_once("simapp --name x --flops 100000 --steps 100", seed)
    });
    b.case("pipeline: graph500 scale 12 (real BFS)", || {
        seed += 1;
        run_once("graph500 --scale 12 --nbfs 2", seed)
    });
    b.case("pipeline: trivial app (framework floor)", || {
        seed += 1;
        run_once("simapp --name x --flops 1 --steps 1", seed)
    });
    // campaign throughput (world reused, store grows)
    let mut world = World::new(99);
    world.add_repo(repo("simapp --name x --flops 100000 --steps 100"));
    let mut day = 0i64;
    b.throughput_case("scheduled pipelines (1/day)", 1.0, "pipelines", || {
        day += 1;
        world.advance_to(exacb::util::timeutil::SimTime::from_days(day));
        world.run_pipeline("app", Trigger::Scheduled).unwrap()
    });
    b.report("perf_e2e");

    let full = b.results()[0].mean.as_secs_f64();
    let floor = b.results()[2].mean.as_secs_f64();
    println!(
        "\nframework floor = {:.3} ms; full pipeline = {:.3} ms; overhead ratio = {:.1}%",
        floor * 1e3,
        full * 1e3,
        100.0 * floor / full
    );
    println!(
        "(the floor includes YAML parse + component validation + scheduler + store commit)"
    );
}
