//! Perf: protocol document parse/serialize throughput (DESIGN.md §8
//! target: parse ≥ 200 MB/s) and Table-I CSV emission.

use exacb::bench::Bench;
use exacb::protocol::{results_csv, DataEntry, Report};
use exacb::util::json::Json;

fn big_report(entries: usize) -> Report {
    let mut r = Report::default();
    r.reporter.tool = "exacb".into();
    r.reporter.tool_version = "0.1.0".into();
    r.reporter.system = "jupiter".into();
    r.reporter.timestamp = "2026-03-01T03:00:00Z".into();
    r.experiment.system = "jupiter".into();
    r.experiment.timestamp = r.reporter.timestamp.clone();
    for i in 0..entries {
        r.data.push(DataEntry {
            success: i % 7 != 0,
            runtime: 12.25 + i as f64,
            nodes: 1 + (i as u64 % 64),
            taskspernode: 4,
            threadspertask: 18,
            jobid: 7_700_000 + i as u64,
            queue: "booster".into(),
            metrics: Json::obj()
                .set("bw_copy", 3_400_000.0 + i as f64)
                .set("bw_triad", 3_450_000.0 + i as f64)
                .set("gflops", 830.25)
                .set("energy_j", 51234.5),
        });
    }
    r
}

fn main() {
    let mut b = Bench::new();
    let small = big_report(1).to_document();
    let large = big_report(500).to_document();
    println!("document sizes: small={} B, large={} B", small.len(), large.len());

    b.throughput_case("parse small report", small.len() as f64, "B", || {
        Report::parse(&small).unwrap()
    });
    b.throughput_case("parse 500-entry report", large.len() as f64, "B", || {
        Report::parse(&large).unwrap()
    });
    let r = big_report(500);
    b.throughput_case("serialize 500-entry report", large.len() as f64, "B", || {
        r.to_document()
    });
    b.case("validate+migrate v1 doc", || {
        let doc = r#"{"version":1,"meta":{"tool":"t","system":"s","timestamp":"2026-01-01"},
                      "runs":[{"success":"true","runtime_s":1.0,"nodes":2}]}"#;
        Report::parse(doc).unwrap()
    });
    b.case("results.csv for 500 entries", || results_csv(&[&r]));
    b.report("perf_protocol");
}
