//! Perf: analysis pipeline — time-series extraction, changepoint
//! detection, scaling computation over campaign-scale report sets.

use exacb::analysis::{ReportSet, StrongScaling};
use exacb::bench::Bench;
use exacb::protocol::{DataEntry, Report};
use exacb::util::json::Json;
use exacb::util::stats::changepoints;
use exacb::util::timeutil::SimTime;

fn campaign_set(days: usize) -> ReportSet {
    let mut reports = Vec::new();
    for d in 0..days {
        let mut r = Report::default();
        r.reporter.tool = "exacb".into();
        r.reporter.tool_version = "0.1".into();
        r.reporter.system = "jupiter".into();
        r.reporter.pipeline_id = 221_600 + d as u64;
        r.reporter.timestamp = SimTime::from_days(d as i64).iso8601();
        r.experiment.system = "jupiter".into();
        r.experiment.timestamp = r.reporter.timestamp.clone();
        for n in [1u64, 2, 4, 8, 16, 32] {
            r.data.push(DataEntry {
                success: true,
                runtime: 100.0 / n as f64 + (d % 5) as f64 * 0.01,
                nodes: n,
                metrics: Json::obj()
                    .set("bw_triad", 3_450_000.0 * if d > days / 2 { 0.8 } else { 1.0 })
                    .set("tts", 100.0 / n as f64),
                ..Default::default()
            });
        }
        reports.push(r);
    }
    ReportSet::from_reports(reports)
}

fn main() {
    let mut b = Bench::new();
    let set = campaign_set(365);
    println!(
        "campaign set: {} reports, {} entries",
        set.len(),
        set.len() * 6
    );
    b.throughput_case("time-series extraction (365d)", 365.0, "reports", || {
        set.time_series("bw_triad").len()
    });
    let series: Vec<f64> = set.time_series("bw_triad").iter().map(|(_, v)| *v).collect();
    b.case("changepoint detection (365 pts)", || {
        changepoints(&series, 8.0).len()
    });
    b.case("strong-scaling analysis", || {
        StrongScaling::from_set(&set, "jupiter", "runtime").unwrap()
    });
    b.case("filter by time span", || {
        set.filter_time_span(
            SimTime::parse("2026-03-01"),
            SimTime::parse("2026-06-01"),
        )
        .len()
    });
    let analysis = exacb::analysis::analyse(&set, "bw_triad", 8.0);
    b.case("render timeseries SVG", || {
        exacb::analysis::timeseries::plot("t", "y", std::slice::from_ref(&analysis), &[])
            .render_svg()
            .len()
    });
    b.report("perf_analysis");
}
