//! Perf: batch-scheduler throughput (DESIGN.md §8 target: ≥ 100k
//! jobs/min simulated) and contention handling.

use exacb::bench::Bench;
use exacb::scheduler::{AccountManager, BatchSystem, JobResult, JobSpec};
use exacb::util::json::Json;

fn submit_run(jobs: usize, nodes_each: u64, partition_nodes: u64) -> usize {
    let mut bs = BatchSystem::new("m", 128, AccountManager::open("a", "b", 1e15));
    bs.add_partition("p", partition_nodes);
    for i in 0..jobs {
        bs.submit(
            JobSpec {
                nodes: nodes_each,
                account: "a".into(),
                budget: "b".into(),
                partition: "p".into(),
                walltime_limit_s: 1_000_000,
                name: format!("j{i}"),
                ..Default::default()
            },
            Box::new(|_| JobResult {
                duration_s: 300.0,
                success: true,
                metrics: Json::obj(),
                files: vec![],
            }),
        )
        .unwrap();
    }
    bs.run_until_idle();
    bs.records().len()
}

fn main() {
    let mut b = Bench::new();
    b.throughput_case("1k jobs, no contention", 1000.0, "jobs", || {
        submit_run(1000, 1, 2000)
    });
    b.throughput_case("1k jobs, 8-node partition (queued)", 1000.0, "jobs", || {
        submit_run(1000, 2, 8)
    });
    b.throughput_case("200 jobs, heavy backfill mix", 200.0, "jobs", || {
        let mut bs = BatchSystem::new("m", 128, AccountManager::open("a", "b", 1e15));
        bs.add_partition("p", 64);
        for i in 0..200usize {
            let nodes = [1u64, 2, 4, 48][i % 4];
            bs.submit(
                JobSpec {
                    nodes,
                    account: "a".into(),
                    budget: "b".into(),
                    partition: "p".into(),
                    walltime_limit_s: 1_000_000,
                    ..Default::default()
                },
                Box::new(move |_| JobResult {
                    duration_s: 60.0 * (1 + nodes) as f64,
                    success: true,
                    metrics: Json::obj(),
                    files: vec![],
                }),
            )
            .unwrap();
        }
        bs.run_until_idle();
        bs.records().len()
    });
    b.report("perf_scheduler");
    // DESIGN.md §8: >= 100k jobs/min == ~1667 jobs/s
    let jobs_per_s = 1000.0 / b.results()[0].mean.as_secs_f64();
    println!(
        "\nno-contention throughput: {:.0} jobs/s (target ≥ 1667 jobs/s == 100k/min): {}",
        jobs_per_s,
        if jobs_per_s >= 1667.0 { "PASS" } else { "MISS" }
    );
}
