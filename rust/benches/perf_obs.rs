//! Perf: observability overhead budgets (DESIGN.md §8, §13).
//!
//! The deterministic observability layer is off by default; these are
//! the budgets that keep it honest:
//!
//! * **disarmed emission is allocation-free** — a tight loop of
//!   disarmed counter/histogram/span/instant emissions allocates
//!   exactly zero bytes, so wiring the event core with emission sites
//!   added nothing to the tracing-off dispatch path;
//! * **armed overhead** — driving the 5 000-app fleet slice with the
//!   tracer and metrics registry fully armed costs at most **15%**
//!   events/s against the disarmed run of the identical campaign;
//! * **armed determinism** — the rendered Chrome trace of an armed
//!   campaign is byte-identical across two replays (the cheap
//!   bench-side echo of the `integration_obs` contract).
//!
//! Like `perf_fleet`, campaign shots are far too heavy for a re-running
//! harness window, so this bench times single shots with `Instant`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use exacb::cluster::{Cluster, EventLog};
use exacb::coordinator::{collection, World};
use exacb::util::timeutil::SimTime;
use exacb::workloads::portfolio::{self, PortfolioApp};

// ---- counting allocator (same pattern as perf_fleet) -------------------

struct CountingAlloc;

static CURRENT: AtomicUsize = AtomicUsize::new(0);
static TOTAL: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            CURRENT.fetch_add(layout.size(), Ordering::Relaxed);
            TOTAL.fetch_add(layout.size(), Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        CURRENT.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                let grow = new_size - layout.size();
                CURRENT.fetch_add(grow, Ordering::Relaxed);
                TOTAL.fetch_add(grow, Ordering::Relaxed);
            } else {
                CURRENT.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Total bytes allocated (cumulative, not peak) while `f` runs — the
/// zero-allocation budget cares about *any* allocation, including ones
/// that are immediately freed and never move the high-water mark.
fn allocated_during<T>(f: impl FnOnce() -> T) -> (T, usize) {
    let before = TOTAL.load(Ordering::Relaxed);
    let out = f();
    (out, TOTAL.load(Ordering::Relaxed) - before)
}

// ---- fleet construction (perf_fleet's uniform fleet, gates off) --------

const SEED: u64 = 20260808;
const MACHINES: usize = 20;
const FLEET_APPS: usize = 5_000;

fn fleet_cluster() -> Cluster {
    let standard = Cluster::standard();
    let base = standard.machine("jedi").expect("jedi exists").clone();
    let mut machines = Vec::with_capacity(MACHINES);
    for i in 0..MACHINES {
        let mut m = base.clone();
        m.name = format!("fleet-{i:02}");
        m.nodes = 64;
        m.queues = vec!["all".into()];
        machines.push(m);
    }
    Cluster {
        machines,
        events: EventLog::new(),
    }
}

fn fleet_apps(n: usize) -> Vec<PortfolioApp> {
    let mut apps = portfolio::generate(n, SEED);
    for app in &mut apps {
        app.failure_rate = 0.0;
    }
    apps
}

struct Shot {
    wall: std::time::Duration,
    events: usize,
    pipelines_ok: usize,
}

/// One cold campaign day over `n` apps with the recorders armed or not.
/// Drains both recorders afterwards so shots are independent.
fn campaign_shot(n: usize, armed: bool) -> (Shot, Vec<exacb::obs::TraceEvent>, String) {
    let apps = fleet_apps(n);
    let machine_names: Vec<String> = (0..MACHINES).map(|i| format!("fleet-{i:02}")).collect();
    let machines: Vec<&str> = machine_names.iter().map(|s| s.as_str()).collect();
    let mut world = World::with_cluster(fleet_cluster(), SEED);
    collection::onboard_multi(&mut world, &apps, &machines, "all");
    exacb::obs::trace::drain();
    exacb::obs::metrics::drain();
    let prior_t = exacb::obs::set_tracing(armed);
    let prior_m = exacb::obs::set_metrics(armed);
    let t0 = Instant::now();
    let summary = collection::run_campaign_concurrent(&mut world, &apps, &machines, 1);
    let wall = t0.elapsed();
    exacb::obs::set_tracing(prior_t);
    exacb::obs::set_metrics(prior_m);
    let events: usize = world.batch.values().map(|b| b.record_count()).sum();
    let trace = exacb::obs::trace::drain();
    let metrics = exacb::obs::metrics::drain();
    (
        Shot {
            wall,
            events,
            pipelines_ok: summary.pipelines_succeeded,
        },
        trace,
        metrics.to_json().pretty(),
    )
}

fn main() {
    println!("perf_obs: observability budgets over the {MACHINES}-machine fleet\n");

    // ---- budget 1: disarmed emission allocates zero bytes --------------
    const DISARMED_CALLS: usize = 1_000_000;
    assert!(!exacb::obs::tracing() && !exacb::obs::metrics_on());
    let (_, disarmed_bytes) = allocated_during(|| {
        for i in 0..DISARMED_CALLS {
            exacb::obs::count(exacb::obs::Ctr::JobsStarted, 1);
            exacb::obs::count_machine("fleet-00", exacb::obs::Ctr::JobsCompleted, 1);
            exacb::obs::observe(exacb::obs::Hist::QueueWaitS, i as i64);
            exacb::obs::trace::span(
                "fleet-00",
                "run",
                SimTime(i as i64),
                SimTime(i as i64 + 5),
                Vec::new(),
            );
            exacb::obs::trace::instant("fleet-00", "tick", SimTime(i as i64), Vec::new());
        }
    });
    println!(
        "  disarmed emission   : {DISARMED_CALLS} x 5 calls, {disarmed_bytes} bytes   budget: 0"
    );

    // ---- budget 2: armed overhead on the 5k-app fleet slice ------------
    let (off, off_trace, _) = campaign_shot(FLEET_APPS, false);
    let off_eps = off.events as f64 / off.wall.as_secs_f64();
    println!(
        "  5000 apps disarmed  : {:>8.2?}  {} events  ({:.0} events/s)",
        off.wall, off.events, off_eps
    );
    let (on, on_trace, _) = campaign_shot(FLEET_APPS, true);
    let on_eps = on.events as f64 / on.wall.as_secs_f64();
    println!(
        "  5000 apps armed     : {:>8.2?}  {} events  ({:.0} events/s)  {} trace events",
        on.wall,
        on.events,
        on_eps,
        on_trace.len()
    );
    let overhead_pct = (off_eps / on_eps.max(1e-9) - 1.0) * 100.0;
    println!("  armed overhead      = {overhead_pct:>9.1}%   budget: <= 15%");

    // ---- budget 3: armed trace bytes reproduce -------------------------
    let (_, rep_a, met_a) = campaign_shot(500, true);
    let (_, rep_b, met_b) = campaign_shot(500, true);
    let json_a = exacb::obs::trace::chrome_trace_json(&rep_a);
    let json_b = exacb::obs::trace::chrome_trace_json(&rep_b);
    println!(
        "  500-app armed replay: {} trace bytes, {} metric bytes, twice\n",
        json_a.len(),
        met_a.len()
    );

    assert_eq!(
        disarmed_bytes, 0,
        "disarmed emission allocated {disarmed_bytes} bytes over {DISARMED_CALLS} iterations"
    );
    assert!(off_trace.is_empty(), "disarmed campaign recorded events");
    assert!(
        !on_trace.is_empty() && on.events > 0 && on.pipelines_ok > 0,
        "armed campaign recorded nothing"
    );
    assert_eq!(
        off.events, on.events,
        "arming changed the number of scheduler events"
    );
    assert!(
        on_eps >= off_eps * 0.85,
        "armed dispatch overhead {overhead_pct:.1}% exceeds the 15% budget \
         ({off_eps:.0} -> {on_eps:.0} events/s)"
    );
    assert_eq!(json_a, json_b, "armed trace bytes diverged across replays");
    assert_eq!(met_a, met_b, "armed metrics bytes diverged across replays");

    println!("perf_obs: all budgets green");
}
