//! Perf: the BYOB definition layer (DESIGN.md §15).
//!
//! Contract under test, with hard assertions:
//!
//! * a 500-definition directory (500 apps across 10 files + machines +
//!   engines) **loads and validates** under a wall budget — discovery,
//!   tomlite parse, typed conversion, and full semantic validation;
//! * definitions are parsed **once at load**: a warm multi-sweep
//!   campaign over the loaded set performs zero additional tomlite
//!   parses (`tomlite::parse_count` is the witness) — campaign days
//!   never re-read the definition tree;
//! * rendering the built-in set is cheap enough to regenerate on every
//!   `--validate-only` CI lint.
//!
//! Single-shot `Instant` timings (the standard harness would re-run the
//! heavy bodies).

use std::time::{Duration, Instant};

use exacb::coordinator::event_loop;
use exacb::defs::{self, AppDef, MeasurePlan};
use exacb::util::tomlite;
use exacb::workloads::portfolio;

const APPS: usize = 500;
const FILES: usize = 10; // app definitions spread over this many files

/// A 500-app definition set: the deterministic portfolio generator's
/// output as data, on the built-in machines and engine.
fn big_set() -> defs::DefSet {
    let mut set = defs::builtin();
    set.apps = portfolio::generate(APPS, 777)
        .iter()
        .map(|a| AppDef {
            name: a.name.clone(),
            domain: a.domain.clone(),
            maturity: a.maturity,
            engine: "simapp".to_string(),
            nodes: a.nodes,
            gflops_total: a.model.gflops_total,
            serial_frac: a.model.serial_frac,
            mem_bound: a.model.mem_bound,
            comm_mb: a.model.comm_mb,
            steps: a.model.steps,
            weak: a.model.weak,
            failure_rate: a.failure_rate,
            primary_metric: "tts".to_string(),
            record_metrics: vec!["tts".to_string(), "gflops_rate".to_string()],
            file: defs::BUILTIN_FILE.to_string(),
        })
        .collect();
    set
}

/// Write `set` into `dir` with the apps split across [`FILES`] files —
/// the shape of a real multi-team definition tree.
fn write_tree(dir: &std::path::Path, set: &defs::DefSet) -> usize {
    std::fs::create_dir_all(dir).unwrap();
    let rendered = defs::render(set);
    let mut files = 0;
    for (name, text) in &rendered {
        if name == "jureap.toml" {
            // split the app file on [[app]] boundaries into FILES chunks
            let blocks: Vec<&str> = text.split("\n[[app]]").collect();
            let header = blocks[0];
            let apps = &blocks[1..];
            let per = apps.len().div_ceil(FILES);
            for (i, chunk) in apps.chunks(per).enumerate() {
                let mut out = String::from(header);
                for b in chunk {
                    out.push_str("\n[[app]]");
                    out.push_str(b);
                }
                std::fs::write(dir.join(format!("apps-{i:03}.toml")), out).unwrap();
                files += 1;
            }
        } else {
            std::fs::write(dir.join(name), text).unwrap();
            files += 1;
        }
    }
    files
}

fn main() {
    println!("perf_defs: BYOB definition directory load + validate\n");

    let dir = std::env::temp_dir().join("exacb_perf_defs");
    let _ = std::fs::remove_dir_all(&dir);
    let set = big_set();
    let n_files = write_tree(&dir, &set);
    println!("  wrote {APPS} apps + {} machines across {n_files} files", set.machines.len());

    // ---- load + validate wall budget -----------------------------------
    let mut load_wall = Duration::MAX;
    let mut loaded = None;
    for _ in 0..3 {
        let t0 = Instant::now();
        let l = defs::load_dir(dir.to_str().unwrap()).expect("tree must load clean");
        load_wall = load_wall.min(t0.elapsed());
        loaded = Some(l);
    }
    let loaded = loaded.unwrap();
    assert_eq!(loaded.apps.len(), APPS);
    assert_eq!(loaded, set, "loaded tree must equal the rendered set bit-for-bit");
    println!("  load+validate        : {load_wall:>9.2?}  ({APPS} apps, {n_files} files)");

    // ---- render cost (the --validate-only lint regenerates nothing, but
    //      the generator pipeline renders; keep it cheap) ----------------
    let t0 = Instant::now();
    let rendered = defs::render(&set);
    let render_wall = t0.elapsed();
    let bytes: usize = rendered.iter().map(|(_, t)| t.len()).sum();
    println!("  render 500 apps      : {render_wall:>9.2?}  ({bytes} bytes)");

    // ---- zero re-parse on warm campaign days ---------------------------
    let plan = MeasurePlan {
        apps: 16,
        days: 2,
        sweeps: 3, // sweep 1 cold, 2..3 warm replays
        ..MeasurePlan::default()
    };
    let parses_before = tomlite::parse_count();
    let t0 = Instant::now();
    let (_, summaries) =
        defs::run_measure_with(&loaded, &plan, event_loop::drive).expect("plan must run");
    let campaign_wall = t0.elapsed();
    let parse_delta = tomlite::parse_count() - parses_before;
    let warm = &summaries[summaries.len() - 1].cache;
    println!(
        "  16-app x 2d x 3 sweeps: {campaign_wall:>8.2?}  cache {warm:?}, {parse_delta} re-parses"
    );

    let _ = std::fs::remove_dir_all(&dir);

    // ---- budgets (DESIGN.md §15 definition-layer contract) -------------
    println!("\n  load+validate 500    budget: < 2 s         actual: {load_wall:.2?}");
    println!("  render 500           budget: < 1 s         actual: {render_wall:.2?}");
    println!("  warm-campaign parses budget: 0             actual: {parse_delta}");

    assert!(
        load_wall < Duration::from_secs(2),
        "500-definition load+validate blew the wall budget: {load_wall:?}"
    );
    assert!(
        render_wall < Duration::from_secs(1),
        "rendering 500 definitions blew the wall budget: {render_wall:?}"
    );
    assert_eq!(
        parse_delta, 0,
        "campaign days re-parsed definitions: parse once at load is the contract"
    );
    assert!(
        warm.hits > 0,
        "warm sweeps must replay from cache, or the zero-re-parse claim is untested: {warm:?}"
    );

    println!("\nperf_defs: all budgets green");
}
