//! Perf: the digest-indexed snapshot + query layer (DESIGN.md §8, §12).
//!
//! Drives a 5 000-app-store × 30-day report history (two machines ×
//! 2 500 apps) and holds the query-layer contract with hard assertions:
//!
//! * snapshot **build** is O(history) once, under a wall budget;
//! * snapshot **refresh** is O(delta): a one-day append onto a 10×
//!   longer history must refresh in near-constant time — and orders of
//!   magnitude under its rebuild cost;
//! * `cmp`/`rank` aggregation stays under per-query latency floors and
//!   parallelises: ranking on 4 shards must beat 1 shard wall-clock
//!   while producing an identical report;
//! * the snapshot read path is **byte-identical** to the legacy
//!   full-walk readers it replaced (History, ReportSet), which survive
//!   exactly as the executable differential reference.
//!
//! The standard `bench` harness re-runs case bodies to fill a measuring
//! window; building 150 000-document snapshots is far too heavy for
//! that, so this bench times single shots with `Instant` directly.

use std::time::{Duration, Instant};

use exacb::analysis::ReportSet;
use exacb::protocol::{DataEntry, Experiment, Report, Reporter};
use exacb::query::{self, Engine};
use exacb::store::{DataStore, Snapshot};
use exacb::tracking::History;
use exacb::util::json::Json;
use exacb::util::timeutil::SimTime;

/// One fully-formed protocol report document. The machine factor skews
/// even-indexed apps toward `m0` and odd ones toward `m1` so cmp and
/// rank see faster, slower, and contested groups; the day term gives
/// Welch something to chew on.
fn report_doc(machine: &str, app_idx: usize, day: i64, pipeline: u64) -> String {
    let base = 1.0 + (app_idx % 97) as f64 * 0.01;
    let factor = if (app_idx % 2 == 0) == (machine == "m0") {
        1.0
    } else {
        1.15
    };
    let jitter = ((app_idx as u64 ^ day as u64).wrapping_mul(2654435761) % 13) as f64 * 0.0015;
    let value = base * factor + jitter;
    let when = SimTime::from_days(day).iso8601();
    Report {
        reporter: Reporter {
            tool: "exacb".into(),
            tool_version: "1".into(),
            pipeline_id: pipeline,
            ci_job_id: pipeline,
            commit: format!("c{:08x}", day / 10),
            user: "exa".into(),
            system: machine.into(),
            system_version: "v1".into(),
            timestamp: when.clone(),
            seed: app_idx as u64,
        },
        parameter: Json::obj(),
        experiment: Experiment {
            system: machine.into(),
            software_version: "v1".into(),
            variant: "base".into(),
            usecase: "bench".into(),
            timestamp: when,
        },
        data: vec![DataEntry {
            success: true,
            runtime: value,
            nodes: 4,
            taskspernode: 4,
            threadspertask: 8,
            jobid: pipeline,
            queue: "all".into(),
            metrics: Json::obj().set("tts", value * 2.0),
        }],
    }
    .to_document()
}

/// One commit per day carrying every (machine, app) report of that day
/// — the shape a daily campaign leaves behind.
fn append_day(store: &mut DataStore, machines: &[&str], apps: usize, day: i64) {
    let mut files = Vec::with_capacity(machines.len() * apps);
    for m in machines {
        for i in 0..apps {
            let pid = day as u64 * 1_000_000 + i as u64;
            files.push((
                format!("{m}.app{i}/{pid}/report.json"),
                report_doc(m, i, day, pid),
            ));
        }
    }
    store.commit("exacb.data", &files, &format!("day {day}"), SimTime::from_days(day));
}

fn seeded_store(machines: &[&str], apps: usize, days: i64) -> DataStore {
    let mut s = DataStore::new();
    for day in 0..days {
        append_day(&mut s, machines, apps, day);
    }
    s
}

/// Min wall over `n` single-shot runs.
fn best_of<T>(n: usize, mut f: impl FnMut() -> T) -> (T, Duration) {
    let mut best: Option<Duration> = None;
    let mut out = None;
    for _ in 0..n {
        let t0 = Instant::now();
        let v = f();
        let d = t0.elapsed();
        if best.map(|b| d < b).unwrap_or(true) {
            best = Some(d);
        }
        out = Some(v);
    }
    (out.unwrap(), best.unwrap())
}

fn main() {
    println!("perf_query: digest-indexed snapshots + parallel cmp/rank\n");

    // ---- build: 2 machines x 2500 apps x 30 days = 150k documents ------
    let machines = ["m0", "m1"];
    const APPS: usize = 2_500;
    const DAYS: i64 = 30;
    let store = seeded_store(&machines, APPS, DAYS);
    let t0 = Instant::now();
    let snap = Snapshot::build(&store, "exacb.data");
    let build_wall = t0.elapsed();
    println!(
        "  build 150k docs     : {:>9.2?}  {} paths, {} docs, {} obs",
        build_wall,
        snap.path_count(),
        snap.doc_count(),
        snap.obs_count()
    );

    // ---- refresh O(delta): 1-day append on 30d vs 300d histories -------
    let mut short_store = seeded_store(&["m0"], 200, 30);
    let mut short_snap = Snapshot::build(&short_store, "exacb.data");
    let t0 = Instant::now();
    let mut long_store = seeded_store(&["m0"], 200, 300);
    let mut long_snap = Snapshot::build(&long_store, "exacb.data");
    let build_long = t0.elapsed();
    let mut refresh_short = Duration::MAX;
    let mut refresh_long = Duration::MAX;
    for k in 0..3 {
        append_day(&mut short_store, &["m0"], 200, 30 + k);
        let t0 = Instant::now();
        assert_eq!(short_snap.refresh(&short_store), 1, "delta must be one commit");
        refresh_short = refresh_short.min(t0.elapsed());
        append_day(&mut long_store, &["m0"], 200, 300 + k);
        let t0 = Instant::now();
        assert_eq!(long_snap.refresh(&long_store), 1);
        refresh_long = refresh_long.min(t0.elapsed());
    }
    assert_eq!(long_snap.rebuilds(), 1, "append-only refresh escalated to a rebuild");
    println!("  build 200-app x 300d: {build_long:>9.2?}");
    println!("  refresh +1d on  30d : {refresh_short:>9.2?}");
    println!("  refresh +1d on 300d : {refresh_long:>9.2?}");
    // refreshed == rebuilt-from-scratch, the core snapshot property
    let scratch = Snapshot::build(&long_store, "exacb.data");
    assert_eq!(long_snap.fingerprint(), scratch.fingerprint());

    // ---- cmp/rank latency + parallel speedup on 300k rows --------------
    let (rows, rows_wall) = best_of(3, || snap.rows());
    println!("  rows() 300k obs     : {rows_wall:>9.2?}  {} rows", rows.len());
    let (rank_seq, rank_wall_1) = best_of(3, || query::rank(&rows, Engine::Machine, 1));
    let (rank_par, rank_wall_4) = best_of(3, || query::rank(&rows, Engine::Machine, 4));
    let speedup = rank_wall_1.as_secs_f64() / rank_wall_4.as_secs_f64();
    println!("  rank 1 shard        : {rank_wall_1:>9.2?}  {} groups", rank_seq.groups.len());
    println!("  rank 4 shards       : {rank_wall_4:>9.2?}  speedup {speedup:.2}x");
    let (cmp_report, cmp_wall) =
        best_of(3, || query::compare(&rows, Engine::Machine, "m0", "m1", 0.95, 4));
    println!(
        "  cmp 4 shards        : {cmp_wall:>9.2?}  {} groups ({} faster, {} slower)",
        cmp_report.rows.len(),
        cmp_report.count("faster"),
        cmp_report.count("slower")
    );

    // ---- byte-identity vs the legacy full-walk readers -----------------
    let t0 = Instant::now();
    let (walk_set, walk_skip) = ReportSet::load(&long_store, "exacb.data", "");
    let walk_wall = t0.elapsed();
    let (snap_set, snap_skip) = ReportSet::from_snapshot(&long_snap, "");
    assert_eq!(walk_set.reports, snap_set.reports, "ReportSet diverged from the reference");
    assert_eq!(walk_skip, snap_skip);
    let (walk_h, _) = History::from_store(&long_store, "exacb.data", "", &["runtime", "tts"]);
    let (snap_h, _) = History::from_snapshot(&long_snap, "", &["runtime", "tts"]);
    assert_eq!(walk_h.total_points(), snap_h.total_points());
    println!("  legacy walk (60k)   : {walk_wall:>9.2?}  (differential reference)\n");

    // ---- budgets (DESIGN.md §8 query-layer contract) -------------------
    println!("  build 150k docs      budget: < 60 s        actual: {build_wall:.2?}");
    println!(
        "  refresh 300d / 30d   budget: < 5x          actual: {:.2}x",
        refresh_long.as_secs_f64() / refresh_short.as_secs_f64().max(1e-3)
    );
    println!(
        "  refresh vs rebuild   budget: < 1/10        actual: 1/{:.0}",
        build_long.as_secs_f64() / refresh_long.as_secs_f64().max(1e-9)
    );
    println!("  rank 4-shard speedup budget: > 1x          actual: {speedup:.2}x");
    println!(
        "  cmp/rank latency     budget: < 5 s each    actual: {cmp_wall:.2?} / {rank_wall_4:.2?}"
    );

    assert_eq!(snap.doc_count(), (APPS as i64 * DAYS * 2) as usize);
    assert!(
        build_wall < Duration::from_secs(60),
        "150k-doc snapshot build blew the wall budget: {build_wall:?}"
    );
    // O(delta): 10x the history must not change the refresh cost class
    assert!(
        refresh_long < refresh_short.max(Duration::from_millis(1)) * 5,
        "refresh is not O(delta): +1 day on 300d cost {refresh_long:?} vs {refresh_short:?} on 30d"
    );
    assert!(
        refresh_long * 10 < build_long,
        "refresh ({refresh_long:?}) is not clearly cheaper than rebuild ({build_long:?})"
    );
    assert!(
        rank_wall_4 < rank_wall_1,
        "parallel rank gained nothing: {rank_wall_4:?} on 4 shards vs {rank_wall_1:?} on 1"
    );
    assert_eq!(rank_seq.groups, rank_par.groups, "sharded rank diverged from sequential");
    assert_eq!(rank_seq.aggregate, rank_par.aggregate);
    assert!(
        cmp_wall < Duration::from_secs(5) && rank_wall_4 < Duration::from_secs(5),
        "query latency floor blown: cmp {cmp_wall:?}, rank {rank_wall_4:?}"
    );
    assert!(
        cmp_report.count("faster") > 0 && cmp_report.count("slower") > 0,
        "the skewed fixture must produce both verdicts"
    );

    println!("\nperf_query: all budgets green");
}
