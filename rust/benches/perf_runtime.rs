//! Perf: PJRT hot path — artifact compile (one-time) vs execute
//! latency, per logmap variant and the stream model. This is the L1/L2
//! performance evidence for EXPERIMENTS.md §Perf (structure-level; on
//! CPU the Pallas kernel runs under interpret-mode lowering).

use exacb::bench::Bench;
use exacb::runtime::{manifest::default_dir, Engine};

fn main() {
    if !default_dir().join("manifest.json").exists() {
        println!("perf_runtime skipped: run `make artifacts` first");
        return;
    }
    let Ok(mut engine) = Engine::load_default() else {
        println!("perf_runtime skipped: engine backend unavailable (build with --features pjrt)");
        return;
    };
    let entries = engine.manifest.entries.clone();

    // one-time compile cost per artifact
    for e in &entries {
        let t0 = std::time::Instant::now();
        match e.kind.as_str() {
            "logmap" => {
                let n = e.n();
                let x = vec![0.4f32; n];
                let r = vec![3.5f32; n];
                engine.run_logmap(&e.name, &x, &r).unwrap();
            }
            _ => {
                engine.run_stream(&e.name, 0.1).unwrap();
            }
        }
        println!(
            "first-run (compile+execute) {:<24} {:>8.1} ms",
            e.name,
            t0.elapsed().as_secs_f64() * 1e3
        );
    }

    // steady-state execute latency + achieved host rates
    let mut b = Bench::new();
    for e in &entries {
        match e.kind.as_str() {
            "logmap" => {
                let n = e.n();
                let x = vec![0.4f32; n];
                let r = vec![3.5f32; n];
                let name = e.name.clone();
                b.throughput_case(
                    &format!("execute {name}"),
                    e.flops as f64 / 1e9,
                    "GFLOP",
                    || engine.run_logmap(&name, &x, &r).unwrap().2,
                );
            }
            _ => {
                let name = e.name.clone();
                b.throughput_case(
                    &format!("execute {name}"),
                    e.bytes as f64 / 1e9,
                    "GB",
                    || engine.run_stream(&name, 0.1).unwrap().1,
                );
            }
        }
    }
    b.report("perf_runtime");
    println!(
        "\ncompilations={} executions={}",
        engine.compilations, engine.executions
    );
}
