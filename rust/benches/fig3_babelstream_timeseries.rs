//! Bench: regenerate Fig. 3 (BabelStream 5-kernel daily time series, 90
//! days of scheduled pipelines on simulated JUPITER) and time it.

fn main() {
    let t0 = std::time::Instant::now();
    let result = exacb::experiments::fig3(90, 2026);
    result.print();
    result.save(std::path::Path::new("out")).ok();
    println!("\n[bench] 90 daily pipelines + analysis in {:.2}s", t0.elapsed().as_secs_f64());
}
