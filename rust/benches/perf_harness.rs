//! Perf: harness front end — YAML spec parsing, parameter-space
//! expansion, substitution, and regex analysis.

use exacb::bench::Bench;
use exacb::harness::{expand_for_step, substitute, BenchmarkSpec, ParamPoint};

const SPEC: &str = r#"
name: sweep
parametersets:
  - name: run
    parameters:
      - name: nodes
        values: [1, 2, 4, 8, 16, 32, 64, 128]
      - name: tasks
        values: [1, 2, 4, 8]
      - name: intensity
        values: [0.5, 1.0, 2.0, 2.4, 4.0]
      - name: impl
        values: [cuda, hip, sycl, kokkos]
steps:
  - name: compile
    do: [cmake -S . -B build]
  - name: execute
    depends: [compile]
    use: [run]
    remote: true
    do:
      - app --nodes $nodes --tasks $tasks --intensity $intensity --impl $impl
analysis:
  - name: runtime
    file: app.out
    regex: "time: ([0-9.eE+-]+)"
    type: float
"#;

fn main() {
    let mut b = Bench::new();
    b.case("parse benchmark spec", || BenchmarkSpec::parse(SPEC).unwrap());
    let spec = BenchmarkSpec::parse(SPEC).unwrap();
    b.throughput_case("expand 640-point space", 640.0, "points", || {
        expand_for_step(&spec, "execute", &[])
    });
    let points = expand_for_step(&spec, "execute", &[]);
    println!("expanded {} points", points.len());
    let point: &ParamPoint = &points[123];
    b.case("substitute command line", || {
        substitute(
            "app --nodes $nodes --tasks $tasks --intensity ${intensity} --impl $impl",
            point,
        )
    });
    b.case("step order (DAG toposort)", || spec.step_order().unwrap());

    // regex analysis over a realistic output file
    let mut output = String::new();
    for i in 0..2000 {
        output.push_str(&format!("step {i} residual 1.2e-{}\n", i % 9));
    }
    output.push_str("time: 123.456\n");
    let re = exacb::util::rex::Rex::new("time: ([0-9.eE+-]+)").unwrap();
    b.throughput_case("regex analysis 2k-line file", output.len() as f64, "B", || {
        re.captures_last(&output).unwrap().get(1).unwrap().to_string()
    });
    b.report("perf_harness");
}
