//! Perf + contract bench for the maturity subsystem (DESIGN.md §10).
//!
//! Asserted contracts (a regression fails the bench binary, like the
//! warm-sweep contract in `perf_e2e` and the gate contracts in
//! `perf_tracking`):
//!
//! * the full JUREAP-scale onboarding campaign — 72 applications × 30
//!   simulated days of daily pipelines through the `maturity-check@v1`
//!   gate on the shared timeline — lands every **planted** transition
//!   on its exact expected day: instrumentation earns
//!   instrumentability, the replay audit earns reproducibility,
//!   breakage demotes when windowed evidence decays, the fix re-earns;
//! * no application ever exceeds its evidence ceiling (never-audited
//!   apps never reach reproducibility, never-instrumented apps never
//!   leave runnability);
//! * a full-collection assessment over all 72 recorded histories
//!   completes within a wall-time budget.
//!
//! Timed cases: single-store evidence assessment, the readiness table,
//! and criteria evaluation.

use exacb::coordinator::World;
use exacb::maturity::{self, assess_world, earned_level, CriteriaConfig};
use exacb::workloads::onboarding::OnboardingScenario;
use exacb::workloads::portfolio::Maturity;

fn main() {
    let days = 30i64;
    let sc = OnboardingScenario::jureap(days);
    assert_eq!(sc.apps.len(), 72);
    let mut world = World::new(sc.seed);

    let t0 = std::time::Instant::now();
    let out = maturity::run_onboarding(&mut world, &sc);
    let campaign_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "campaign: 72 apps x {days} days, {}/{} pipelines succeeded, \
         {} transitions, {:.0} ms wall ({:.0} pipelines/s)",
        out.pipelines_succeeded,
        out.pipelines_run,
        out.transitions.len(),
        campaign_ms,
        out.pipelines_run as f64 / (campaign_ms / 1e3)
    );

    // ---- contract: planted promotions land on the exact earn day ------
    let mut checked = (0usize, 0usize, 0usize, 0usize);
    for (i, oa) in sc.apps.iter().enumerate() {
        let name = oa.app.name.as_str();
        if oa.declared == Maturity::Runnability {
            if let Some(expect) = sc.expected_instrumentability_day(i) {
                assert_eq!(
                    out.transition_day(name, Maturity::Instrumentability),
                    Some(expect),
                    "{name}: planted instrumentation must earn on day {expect}: {:?}",
                    out.transitions_of(name)
                );
                checked.0 += 1;
            }
        }
        if oa.declared == Maturity::Instrumentability && oa.verify_from.is_some() {
            let expect = sc.expected_reproducibility_day(i).unwrap();
            assert_eq!(
                out.transition_day(name, Maturity::Reproducibility),
                Some(expect),
                "{name}: replay audit must earn the top rung on day {expect}: {:?}",
                out.transitions_of(name)
            );
            checked.1 += 1;
        }
        if let (Some(_), Some(fix)) = (oa.break_day, oa.fix_day) {
            let demote = sc.expected_demotion_day(i).unwrap();
            let reearn = sc.expected_repromotion_day(i).unwrap();
            assert_eq!(
                out.transition_day(name, Maturity::Runnability),
                Some(demote),
                "{name}: windowed evidence must decay to a demotion on day {demote}: {:?}",
                out.transitions_of(name)
            );
            let back = out
                .transitions_of(name)
                .into_iter()
                .find(|t| t.day >= fix && t.to == Maturity::Instrumentability)
                .unwrap_or_else(|| panic!("{name}: no re-promotion after the fix"));
            assert_eq!(
                back.day, reearn,
                "{name}: the fix must re-earn instrumentability on day {reearn}"
            );
            checked.2 += 1;
        }
        if oa.declared == Maturity::Reproducibility {
            // re-earning the declared top rung: first audit day after
            // the evidence floor
            let expect = sc.expected_reproducibility_day(i).unwrap();
            assert_eq!(
                out.transition_day(name, Maturity::Reproducibility),
                Some(expect),
                "{name}: declared reproducibility must be re-earned on day {expect}: {:?}",
                out.transitions_of(name)
            );
            checked.3 += 1;
        }
    }
    assert!(
        checked.0 >= 1 && checked.1 >= 1 && checked.2 >= 1 && checked.3 >= 1,
        "every planted class must occur: {checked:?}"
    );
    println!(
        "planted transitions exact: {} instrumentations, {} audits, \
         {} break/fix cycles, {} re-earned declarations",
        checked.0, checked.1, checked.2, checked.3
    );

    // ---- contract: nobody exceeds their evidence ceiling --------------
    for oa in &sc.apps {
        let level = world.repo(&oa.app.name).unwrap().maturity;
        if oa.verify_from.is_none() {
            assert!(
                level < Maturity::Reproducibility,
                "{}: reproducibility without a replay audit",
                oa.app.name
            );
        }
        if oa.instrument_from.is_none() {
            assert_eq!(
                level,
                Maturity::Runnability,
                "{}: instrumentability without instrumentation",
                oa.app.name
            );
        }
    }
    println!("evidence ceilings hold for all 72 applications");

    // ---- contract: full-collection assessment under a wall budget -----
    let cfg = CriteriaConfig::default();
    let t1 = std::time::Instant::now();
    let states = assess_world(&world, &cfg);
    let assess_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert_eq!(states.len(), 72);
    let total_reports: usize = states.iter().map(|s| s.evidence.reports).sum();
    const ASSESS_BUDGET_MS: f64 = 5_000.0;
    assert!(
        assess_ms < ASSESS_BUDGET_MS,
        "full-collection assessment took {assess_ms:.0} ms (budget {ASSESS_BUDGET_MS} ms)"
    );
    println!(
        "full-collection assessment: 72 stores, {total_reports} distinct reports \
         in {assess_ms:.1} ms (budget {ASSESS_BUDGET_MS:.0} ms)"
    );

    // ---- timed cases --------------------------------------------------
    let mut b = exacb::bench::Bench::quick();
    let busiest = sc
        .apps
        .iter()
        .enumerate()
        .max_by_key(|(i, _)| {
            world
                .repo(&sc.apps[*i].app.name)
                .map(|r| r.store.list("exacb.data", "").len())
                .unwrap_or(0)
        })
        .map(|(_, oa)| oa.app.name.clone())
        .unwrap();
    let repo = world.repo(&busiest).unwrap().clone();
    b.throughput_case(
        "assess: one 30-day store",
        days as f64,
        "days",
        || maturity::assess_repo(&repo, &cfg),
    );
    b.case("maturity_table: 72-app readiness view", || {
        maturity::maturity_table(&world, &cfg)
    });
    let sample = states
        .iter()
        .find(|s| s.evidence.successful_runs > 0)
        .unwrap();
    b.case("criteria: earned_level over evidence", || {
        earned_level(&sample.evidence, &cfg)
    });
    b.report("perf_maturity");
    println!("\nall maturity contracts held");
}
