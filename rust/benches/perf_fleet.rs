//! Perf: fleet-scale event dispatch (DESIGN.md §8).
//!
//! Drives a 5 000-app × 20-machine campaign — cold, then a warm cache
//! sweep — with regression and maturity gates armed on a slice of the
//! portfolio, and holds the O(log n) dispatch contract with hard
//! assertions:
//!
//! * completed scheduler events per second of real wall time,
//! * peak-allocation budget for the cold campaign,
//! * the scaling law: 10× the apps must cost **less than 20×** the
//!   dispatch wall time (a linear-scan event loop rescans every task and
//!   machine per event, so its total cost grows quadratically and fails
//!   this bound),
//! * the incremental-execution contract under gates: a warm sweep may
//!   submit only the regression gate's measurement repetitions, nothing
//!   else.
//!
//! The standard `bench` harness re-runs case bodies to fill a measuring
//! window; a 5k-app campaign is far too heavy for that, so this bench
//! times single shots with `Instant` directly.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use exacb::cluster::{Cluster, EventLog};
use exacb::coordinator::{collection, World};
use exacb::workloads::portfolio::{self, PortfolioApp};

// ---- counting allocator: peak-memory budget enforcement ---------------

struct CountingAlloc;

static CURRENT: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let cur = CURRENT.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(cur, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        CURRENT.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                let grow = new_size - layout.size();
                let cur = CURRENT.fetch_add(grow, Ordering::Relaxed) + grow;
                PEAK.fetch_max(cur, Ordering::Relaxed);
            } else {
                CURRENT.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Reset the peak to the current live size and return bytes allocated
/// beyond it by `f` at the high-water mark.
fn peak_during<T>(f: impl FnOnce() -> T) -> (T, usize) {
    let base = CURRENT.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    let out = f();
    (out, PEAK.load(Ordering::Relaxed).saturating_sub(base))
}

// ---- fleet construction ------------------------------------------------

const SEED: u64 = 20260808;
const MACHINES: usize = 20;
const GATE_REG_EVERY: usize = 50; // i % 50 == 0 → regression-check@v1
const GATE_MAT_EVERY: usize = 10; // else i % 10 == 0 → maturity-check@v1
const GATE_MAX_REPS: usize = 4; // min_repetitions + max_extra_repetitions

/// Twenty 64-node single-partition machines cloned from jedi's hardware
/// model — a uniform fleet so placement round-robin spreads the
/// portfolio evenly.
fn fleet_cluster() -> Cluster {
    let standard = Cluster::standard();
    let base = standard.machine("jedi").expect("jedi exists").clone();
    let mut machines = Vec::with_capacity(MACHINES);
    for i in 0..MACHINES {
        let mut m = base.clone();
        m.name = format!("fleet-{i:02}");
        m.nodes = 64;
        m.queues = vec!["all".into()];
        machines.push(m);
    }
    Cluster {
        machines,
        events: EventLog::new(),
    }
}

fn fleet_apps(n: usize) -> Vec<PortfolioApp> {
    let mut apps = portfolio::generate(n, SEED);
    for app in &mut apps {
        // flaky injection patches repo files per day — noise this bench
        // does not want in its throughput or cache numbers
        app.failure_rate = 0.0;
    }
    apps
}

/// Overwrite the CI file of every gated app: each 50th app gets the
/// regression gate, each remaining 10th the maturity gate (assess mode)
/// — so the campaign exercises gates that run batch jobs *inside* task
/// polls and gates that only read evidence. Returns (regression-gated,
/// maturity-gated) counts.
fn arm_gates(world: &mut World, assignments: &[(String, String)]) -> (usize, usize) {
    let (mut reg, mut mat) = (0usize, 0usize);
    for (i, (app, machine)) in assignments.iter().enumerate() {
        let prefix = format!("{machine}.{app}");
        let execution = format!(
            r#"include:
  - component: execution@v3
    inputs:
      prefix: "{prefix}"
      machine: "{machine}"
      queue: "all"
      project: "cexalab"
      budget: "exalab"
      jube_file: "benchmark/jube/app.yml"
      record: "true"
"#
        );
        let ci = if i % GATE_REG_EVERY == 0 {
            reg += 1;
            format!(
                r#"{execution}  - component: regression-check@v1
    inputs:
      prefix: "{prefix}"
      machine: "{machine}"
      queue: "all"
      project: "cexalab"
      budget: "exalab"
      jube_file: "benchmark/jube/app.yml"
      metric: "runtime"
      threshold_pct: 10
      confidence_pct: 95
      min_repetitions: 2
      max_extra_repetitions: 2
      baseline_window: 10
      min_baseline: 4
schedule:
  every: day
  hour: 3
"#
            )
        } else if i % GATE_MAT_EVERY == 0 {
            mat += 1;
            format!(
                r#"{execution}  - component: maturity-check@v1
    inputs:
      prefix: "{prefix}"
      min_runs: 2
      min_instrumented: 2
      window_days: 30
schedule:
  every: day
  hour: 3
"#
            )
        } else {
            continue;
        };
        let repo = world.repos.get_mut(app).expect("onboarded repo");
        for (path, content) in repo.files.iter_mut() {
            if path == ".gitlab-ci.yml" {
                *content = ci.clone();
            }
        }
    }
    (reg, mat)
}

struct FleetRun {
    summary: collection::CollectionSummary,
    wall: std::time::Duration,
    events: usize,
    gated_reg: usize,
    world: World,
}

/// Onboard `n` apps on the fleet, arm the gates, run one cold campaign
/// day through the concurrent event loop.
fn cold_campaign(n: usize) -> (FleetRun, usize) {
    let apps = fleet_apps(n);
    let machine_names: Vec<String> = (0..MACHINES).map(|i| format!("fleet-{i:02}")).collect();
    let machines: Vec<&str> = machine_names.iter().map(|s| s.as_str()).collect();
    let mut world = World::with_cluster(fleet_cluster(), SEED);
    world.enable_cache();
    let assignments = collection::onboard_multi(&mut world, &apps, &machines, "all");
    let (gated_reg, _gated_mat) = arm_gates(&mut world, &assignments);
    let ((summary, wall), peak) = peak_during(|| {
        let t0 = Instant::now();
        let summary = collection::run_campaign_concurrent(&mut world, &apps, &machines, 1);
        (summary, t0.elapsed())
    });
    let events: usize = world.batch.values().map(|b| b.record_count()).sum();
    (
        FleetRun {
            summary,
            wall,
            events,
            gated_reg,
            world,
        },
        peak,
    )
}

fn main() {
    println!("perf_fleet: {MACHINES}-machine fleet, concurrent dispatch, gates armed\n");

    // ---- scaling baseline: 500 apps ------------------------------------
    let (small, _) = cold_campaign(500);
    println!(
        "  500 apps cold : {:>8.2?}  {} events  {} pipelines ({} ok)",
        small.wall, small.events, small.summary.pipelines_run, small.summary.pipelines_succeeded
    );

    // ---- the fleet: 5 000 apps, cold -----------------------------------
    let (big, peak) = cold_campaign(5_000);
    println!(
        "  5000 apps cold: {:>8.2?}  {} events  {} pipelines ({} ok)  peak +{:.0} MiB",
        big.wall,
        big.events,
        big.summary.pipelines_run,
        big.summary.pipelines_succeeded,
        peak as f64 / (1024.0 * 1024.0)
    );

    // ---- warm cache sweep over the same day ----------------------------
    let mut world = big.world;
    let apps = fleet_apps(5_000);
    let machine_names: Vec<String> = (0..MACHINES).map(|i| format!("fleet-{i:02}")).collect();
    let machines: Vec<&str> = machine_names.iter().map(|s| s.as_str()).collect();
    let hits_cold = world.cache_stats().hits;
    let t0 = Instant::now();
    let warm_summary = collection::run_campaign_concurrent(&mut world, &apps, &machines, 1);
    let warm_wall = t0.elapsed();
    let events_warm: usize = world.batch.values().map(|b| b.record_count()).sum();
    let new_submissions = events_warm - big.events;
    println!(
        "  5000 apps warm: {:>8.2?}  {} new submissions  {} pipelines ({} ok)\n",
        warm_wall, new_submissions, warm_summary.pipelines_run, warm_summary.pipelines_succeeded
    );

    // ---- budgets (DESIGN.md §8 fleet-dispatch contract) ----------------
    let events_per_s = big.events as f64 / big.wall.as_secs_f64();
    let scale = big.wall.as_secs_f64() / small.wall.as_secs_f64().max(0.05);
    println!("  events/s (cold 5k)  = {events_per_s:>10.0}   budget: >= 50");
    println!(
        "  peak alloc (cold 5k) = {:>8.0} MiB   budget: < 2048 MiB",
        peak as f64 / (1024.0 * 1024.0)
    );
    println!("  wall 5k / wall 500   = {scale:>9.1}x   budget: < 20x");
    println!(
        "  warm submissions     = {new_submissions:>10}   budget: <= {}",
        big.gated_reg * GATE_MAX_REPS
    );

    assert_eq!(
        big.summary.pipelines_run, 5_000,
        "one work item per app per day"
    );
    assert!(
        big.summary.pipelines_succeeded * 5 >= big.summary.pipelines_run * 4,
        "at least 80% of fleet pipelines succeed: {}/{}",
        big.summary.pipelines_succeeded,
        big.summary.pipelines_run
    );
    assert!(
        events_per_s >= 50.0,
        "fleet dispatch below the events/s floor: {events_per_s:.0}/s"
    );
    assert!(
        peak < 2 * 1024 * 1024 * 1024,
        "cold 5k campaign peaked at {peak} bytes (budget 2 GiB)"
    );
    // the O(log n) law: 10x the apps must cost < 20x the wall. A
    // per-event linear rescan of tasks/machines makes total cost grow
    // ~quadratically in apps and blows this bound.
    assert!(
        scale < 20.0,
        "dispatch scaling is super-linear: 10x apps cost {scale:.1}x wall"
    );
    // warm sweep: executions replay from cache; only the regression
    // gate's measurement repetitions may hit the batch systems
    assert!(
        new_submissions <= big.gated_reg * GATE_MAX_REPS,
        "warm sweep submitted {new_submissions} jobs; only {} gate repetitions are allowed",
        big.gated_reg * GATE_MAX_REPS
    );
    assert!(
        world.cache_stats().hits > hits_cold,
        "warm sweep produced no cache hits"
    );

    println!("\nperf_fleet: all budgets green");
}
