//! Bench: regenerate Fig. 4 (Graph500 two-kernel daily time series with
//! a network regression at day 30 and recovery at day 60) and time it.
//! Each daily pipeline runs a REAL BFS over a Kronecker graph.

fn main() {
    let t0 = std::time::Instant::now();
    let result = exacb::experiments::fig4(90, 2026);
    result.print();
    result.save(std::path::Path::new("out")).ok();
    println!("\n[bench] 90 daily pipelines (real BFS) + changepoints in {:.2}s", t0.elapsed().as_secs_f64());
}
