//! Perf + contract bench for the energy subsystem (DESIGN.md §11).
//!
//! Asserted contracts (a regression fails the bench binary, like the
//! warm-sweep contract in `perf_e2e` and the gate contracts in
//! `perf_tracking` / `perf_maturity`):
//!
//! * the concurrent 24-app × 8-frequency collection sweep — every point
//!   of every eligible application interleaved on the shared batch
//!   timeline across three machines — completes in **strictly less
//!   simulated time** than sequential dispatch;
//! * concurrent and sequential dispatch agree byte-for-byte on the
//!   analysis (per-point PRNG streams make the noise
//!   interleaving-independent);
//! * every **planted energy bowl** (memory-boundedness swept 0.15→0.85
//!   across the portfolio) recovers its analytic sweet spot within one
//!   frequency step of the sweep grid;
//! * no sweep produces a NaN anywhere in its summary.

use exacb::coordinator::World;
use exacb::energy::study;
use exacb::workloads::onboarding::{OnboardingApp, OnboardingScenario};
use exacb::workloads::portfolio::{Maturity, PortfolioApp};
use exacb::workloads::scalable::AppModel;

const APPS: usize = 24;
const POINTS: usize = 8;
const MACHINES: [&str; 3] = ["jupiter", "jedi", "jureca"];

/// 24 eligible applications with planted energy bowls: single-node,
/// communication-free, memory-boundedness swept linearly so every app's
/// analytic sweet spot is computable from its machine's power model.
fn scenario() -> OnboardingScenario {
    let apps = (0..APPS)
        .map(|i| {
            let mem_bound = 0.15 + 0.70 * i as f64 / (APPS - 1) as f64;
            let name = format!("energy-{i:02}");
            OnboardingApp {
                app: PortfolioApp {
                    name: name.clone(),
                    domain: "energy".to_string(),
                    maturity: Maturity::Reproducibility,
                    model: AppModel {
                        name,
                        gflops_total: 300_000.0,
                        serial_frac: 0.01,
                        mem_bound,
                        comm_mb: 0.0,
                        steps: 20,
                        weak: false,
                    },
                    failure_rate: 0.0,
                    nodes: 1,
                },
                declared: Maturity::Reproducibility,
                instrument_from: Some(0),
                verify_from: Some(0),
                break_day: None,
                fix_day: None,
            }
        })
        .collect();
    OnboardingScenario {
        apps,
        days: 1,
        machines: MACHINES.iter().map(|m| m.to_string()).collect(),
        queue: "all".to_string(),
        seed: 20260601,
        verify_every: 4,
        min_runs: 3,
        min_instrumented: 3,
        window_days: 0,
    }
}

fn main() {
    let sc = scenario();

    // ---- sequential baseline ------------------------------------------
    let mut seq = World::new(sc.seed);
    study::onboard_declared(&mut seq, &sc);
    let t0 = std::time::Instant::now();
    let seq_out = study::run_energy_campaign(&mut seq, &sc, POINTS, false);
    let seq_wall = t0.elapsed().as_secs_f64();
    let seq_sim = seq.now().0;

    // ---- concurrent sweep ---------------------------------------------
    let mut con = World::new(sc.seed);
    study::onboard_declared(&mut con, &sc);
    let t0 = std::time::Instant::now();
    let con_out = study::run_energy_campaign(&mut con, &sc, POINTS, true);
    let con_wall = t0.elapsed().as_secs_f64();
    let con_sim = con.now().0;

    println!(
        "campaign: {APPS} apps x {POINTS} frequencies on {} machines ({} jobs)",
        MACHINES.len(),
        APPS * POINTS
    );
    println!(
        "  sequential: {seq_sim:>8} simulated s, {:>7.1} ms wall",
        seq_wall * 1e3
    );
    println!(
        "  concurrent: {con_sim:>8} simulated s, {:>7.1} ms wall  (sim speedup {:.1}x)",
        con_wall * 1e3,
        seq_sim as f64 / con_sim.max(1) as f64
    );

    // ---- contract: concurrent beats sequential in simulated time ------
    assert!(
        con_sim < seq_sim,
        "concurrent sweep must finish in strictly less simulated time: \
         {con_sim}s vs {seq_sim}s"
    );

    // ---- contract: both dispatch modes agree on the analysis ----------
    assert_eq!(seq_out.swept.len(), APPS);
    assert_eq!(con_out.swept.len(), APPS);
    for (a, b) in seq_out.swept.iter().zip(&con_out.swept) {
        let (sa, sb) = (
            a.summary.as_ref().expect("sequential sweep analysed"),
            b.summary.as_ref().expect("concurrent sweep analysed"),
        );
        assert_eq!(
            sa.sweet_spot_mhz, sb.sweet_spot_mhz,
            "{}: dispatch mode must not change the sweet spot",
            a.app
        );
        assert_eq!(sa.energy_nominal_j, sb.energy_nominal_j, "{}", a.app);
    }

    // ---- contract: planted bowls recover their sweet spots ------------
    let mut recovered = 0usize;
    let mut with_saving = 0usize;
    for (i, s) in con_out.swept.iter().enumerate() {
        let summary = s.summary.as_ref().expect("sweep analysed");
        let m = con.cluster.machine(&s.machine).unwrap();
        let (lo, hi) = (m.power.min_mhz, m.power.nominal_mhz);
        let step = (hi - lo) / (POINTS - 1) as f64;
        let mb = sc.apps[i].app.model.mem_bound;
        let util = 0.95 - 0.25 * mb;
        // the analytic minimum of the same power/perf model, on the same
        // grid the sweep sampled
        let expected = (0..POINTS)
            .map(|k| lo + step * k as f64)
            .min_by(|a, b| {
                m.power
                    .energy_j(*a, 100.0, util, mb)
                    .partial_cmp(&m.power.energy_j(*b, 100.0, util, mb))
                    .unwrap()
            })
            .unwrap();
        assert!(
            (summary.sweet_spot_mhz - expected).abs() <= step + 1e-6,
            "{} (mem_bound {mb:.2} on {}): recovered {:.0} MHz, analytic {expected:.0} MHz, \
             step {step:.0}",
            s.app,
            s.machine,
            summary.sweet_spot_mhz
        );
        recovered += 1;
        if summary.saving_vs_nominal > 0.0 {
            with_saving += 1;
        }
        // no NaN anywhere in the summary
        for v in [
            summary.sweet_spot_mhz,
            summary.edp_spot_mhz,
            summary.energy_nominal_j,
            summary.energy_spot_j,
            summary.saving_vs_nominal,
        ] {
            assert!(v.is_finite(), "{}: non-finite summary value", s.app);
        }
    }
    println!(
        "sweet spots: {recovered}/{APPS} recovered within one grid step, \
         {with_saving} with a positive saving, projected collection saving {:.1}%",
        con_out.projected_saving_frac() * 100.0
    );
    assert_eq!(recovered, APPS);
    assert!(
        with_saving > APPS / 2,
        "most planted bowls must show a positive sweet-spot saving ({with_saving}/{APPS})"
    );
    println!("\nperf_energy contracts OK");
}
