//! Bench: regenerate Fig 5 strong scaling comparison through the full stack and time it.
//! Prints the same rows/series the paper reports (see EXPERIMENTS.md).

fn main() {
    let t0 = std::time::Instant::now();
    let result = exacb::experiments::fig5(2026);
    result.print();
    result.save(std::path::Path::new("out")).ok();
    println!("\n[bench] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
