//! Perf: the 30-day chaos campaign (DESIGN.md §14).
//!
//! Runs the standard armed chaos scenario — seeded node failures and
//! preemptions, a scheduler outage, a maintenance drain, a fleet-wide
//! stack-update day, a forced-flaky week — and holds the fault model to
//! hard budgets:
//!
//! * completed scheduler events per second of real wall time (the fault
//!   machinery rides the same O(log n) heap as fault-free dispatch),
//! * a peak-allocation budget for the full 30-day campaign,
//! * the determinism budget: an immediate replay of the same scenario
//!   must reproduce the `sacct` timeline **byte-identically**,
//! * an overhead bound: chaos may not cost more than 15× the same
//!   campaign with the inert (zero-rate) scenario.
//!
//! The standard `bench` harness re-runs case bodies to fill a measuring
//! window; a 30-day campaign is too heavy for that, so this bench times
//! single shots with `Instant` directly.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use exacb::coordinator::World;
use exacb::scheduler::JobState;
use exacb::workloads::chaos::{self, ChaosScenario};

// ---- counting allocator: peak-memory budget enforcement ---------------

struct CountingAlloc;

static CURRENT: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let cur = CURRENT.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(cur, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        CURRENT.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                let grow = new_size - layout.size();
                let cur = CURRENT.fetch_add(grow, Ordering::Relaxed) + grow;
                PEAK.fetch_max(cur, Ordering::Relaxed);
            } else {
                CURRENT.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Reset the peak to the current live size and return bytes allocated
/// beyond it by `f` at the high-water mark.
fn peak_during<T>(f: impl FnOnce() -> T) -> (T, usize) {
    let base = CURRENT.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    let out = f();
    (out, PEAK.load(Ordering::Relaxed).saturating_sub(base))
}

// ---- the campaign ------------------------------------------------------

const SEED: u64 = 20260807;
const APPS: usize = 12;
const DAYS: i64 = 30;

struct ChaosRun {
    wall: std::time::Duration,
    events: usize,
    faults: usize,
    pipelines_run: usize,
    pipelines_succeeded: usize,
    sacct: String,
}

/// The `sacct` timeline the determinism budget compares byte-for-byte.
fn sacct_dump(world: &World) -> String {
    let mut out = String::new();
    for (name, bs) in &world.batch {
        for r in bs.records_iter() {
            out.push_str(&format!(
                "{name} {} {} {:?} {:?} {:?} {:?}\n",
                r.jobid,
                r.state.name(),
                r.submit_time,
                r.start_time,
                r.end_time,
                r.result.as_ref().map(|res| (res.success, res.duration_s)),
            ));
        }
    }
    out
}

fn run(scenario: &ChaosScenario) -> ChaosRun {
    let mut world = World::new(SEED);
    let t0 = Instant::now();
    let summary = chaos::run_chaos_campaign(&mut world, scenario);
    let wall = t0.elapsed();
    let events: usize = world.batch.values().map(|b| b.record_count()).sum();
    let faults = world
        .batch
        .values()
        .flat_map(|b| b.records_iter())
        .filter(|r| matches!(r.state, JobState::NodeFail | JobState::Preempted))
        .count();
    ChaosRun {
        wall,
        events,
        faults,
        pipelines_run: summary.pipelines_run,
        pipelines_succeeded: summary.pipelines_succeeded,
        sacct: sacct_dump(&world),
    }
}

fn main() {
    println!("perf_chaos: {APPS} apps x {DAYS} days, armed fault model\n");

    let armed_sc = ChaosScenario::generate(APPS, DAYS, SEED);
    let (armed, peak) = peak_during(|| run(&armed_sc));
    println!(
        "  armed : {:>8.2?}  {} events  {} faults  {} pipelines ({} ok)  peak +{:.0} MiB",
        armed.wall,
        armed.events,
        armed.faults,
        armed.pipelines_run,
        armed.pipelines_succeeded,
        peak as f64 / (1024.0 * 1024.0)
    );

    let replay = run(&armed_sc);
    println!("  replay: {:>8.2?}  {} events", replay.wall, replay.events);

    let quiet_sc = ChaosScenario::quiet(APPS, DAYS, SEED);
    let quiet = run(&quiet_sc);
    println!(
        "  quiet : {:>8.2?}  {} events  {} faults\n",
        quiet.wall, quiet.events, quiet.faults
    );

    // ---- budgets (DESIGN.md §14 chaos contract) ------------------------
    let events_per_s = armed.events as f64 / armed.wall.as_secs_f64();
    let overhead = armed.wall.as_secs_f64() / quiet.wall.as_secs_f64().max(0.05);
    println!("  events/s (armed)   = {events_per_s:>10.0}   budget: >= 50");
    println!(
        "  peak alloc (armed) = {:>8.0} MiB   budget: < 1024 MiB",
        peak as f64 / (1024.0 * 1024.0)
    );
    println!("  armed / quiet wall = {overhead:>9.1}x   budget: < 15x");
    println!(
        "  replay determinism = {:>10}   budget: byte-identical",
        if armed.sacct == replay.sacct { "ok" } else { "BROKEN" }
    );

    assert_eq!(
        armed.pipelines_run,
        APPS * DAYS as usize,
        "one pipeline per app per day"
    );
    assert!(
        armed.faults > 0,
        "the armed campaign never faulted — the scenario is vacuous"
    );
    assert!(
        armed.pipelines_succeeded < armed.pipelines_run,
        "the forced-flaky week must fail some pipelines"
    );
    assert!(
        armed.pipelines_succeeded * 2 > armed.pipelines_run,
        "chaos degraded more than half the campaign: {}/{}",
        armed.pipelines_succeeded,
        armed.pipelines_run
    );
    assert_eq!(quiet.faults, 0, "the inert scenario must never fault");
    assert!(
        events_per_s >= 50.0,
        "chaos dispatch below the events/s floor: {events_per_s:.0}/s"
    );
    assert!(
        peak < 1024 * 1024 * 1024,
        "30-day chaos campaign peaked at {peak} bytes (budget 1 GiB)"
    );
    assert!(
        armed.sacct == replay.sacct,
        "chaos replay is not byte-identical (determinism budget)"
    );
    assert!(
        overhead < 15.0,
        "fault model overhead {overhead:.1}x exceeds the 15x budget"
    );

    println!("\nperf_chaos: all budgets green");
}
