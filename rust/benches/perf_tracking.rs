//! Perf + contract bench for the tracking subsystem (DESIGN.md §9).
//!
//! Asserted contracts (a regression fails the bench binary, like the
//! warm-sweep contract in `perf_e2e`):
//!
//! * a planted >=10% slowdown in a 30-day campaign fails the
//!   `regression-check` gate on the injection day — detected within the
//!   extra-repetition budget — and never before it;
//! * change-point segmentation over the reconstructed history localises
//!   the planted step;
//! * a 0%-shift control series stays green across the whole 30-day
//!   campaign, every gated day spending exactly the adaptive minimum of
//!   extra repetitions.
//!
//! Timed cases: history reconstruction from a campaign-sized store,
//! Welch classification, and rolling-baseline annotation.

use exacb::bench::Bench;
use exacb::coordinator::World;
use exacb::tracking::{self, Detector, History};
use exacb::util::prng::Prng;
use exacb::workloads::regression::RegressionScenario;

fn main() {
    let days = 30i64;
    let inject = 20i64;
    let shift = 15.0; // nominal; effective runtime step stays >= 10%

    // ---- contract: planted regression is caught ----------------------
    let sc = RegressionScenario::planted("jedi", days, inject, shift, 20260730);
    let mut world = World::new(sc.seed);
    let outcome = tracking::run_scenario(&mut world, &sc);
    assert!(
        outcome.failed_days.contains(&inject),
        "planted {}% step must fail the gate on day {inject}; failed: {:?}",
        shift,
        outcome.failed_days
    );
    assert!(
        outcome.failed_days.iter().all(|d| *d >= inject),
        "no false positive before the planted change: {:?}",
        outcome.failed_days
    );
    assert_eq!(outcome.verdict_on(inject), Some("regression"));
    let extra = outcome.extra_reps_on(inject).unwrap();
    assert!(
        extra <= sc.max_extra_repetitions,
        "detection took {extra} extra repetitions, budget {}",
        sc.max_extra_repetitions
    );
    println!(
        "planted {shift}% step: caught on day {inject} with {extra} extra repetition(s) \
         (budget {})",
        sc.max_extra_repetitions
    );

    // ---- contract: segmentation localises the step --------------------
    let repo = world.repo(&sc.app).unwrap();
    let (hist, _) = History::from_store(&repo.store, "exacb.data", "", &["runtime"]);
    let series = hist.series();
    assert_eq!(series.len(), 1);
    let points = &series[0].points;
    let values = series[0].values();
    let boundary = points
        .iter()
        .position(|p| {
            p.time >= exacb::util::timeutil::SimTime::from_days(inject)
        })
        .expect("post-inject points exist");
    let segs = tracking::segment(&values, 5.0);
    let step = segs
        .iter()
        .find(|(cp, v)| {
            *v == tracking::Verdict::Regression
                && cp.index >= boundary.saturating_sub(4)
                && cp.index <= boundary + 4
        });
    assert!(
        step.is_some(),
        "segmentation must localise the step near point {boundary}; got {:?}",
        segs.iter().map(|(cp, v)| (cp.index, *v)).collect::<Vec<_>>()
    );
    let (cp, _) = step.unwrap();
    assert!(
        cp.after > cp.before * 1.08,
        "detected step too small: {} -> {}",
        cp.before,
        cp.after
    );
    println!(
        "segmentation: step at point {} (expected ~{boundary}), {:.2}s -> {:.2}s",
        cp.index, cp.before, cp.after
    );

    // ---- contract: 0%-shift control stays green -----------------------
    let control = RegressionScenario::control("jedi", days, 20260731);
    let mut green = World::new(control.seed);
    let quiet = tracking::run_scenario(&mut green, &control);
    assert!(
        quiet.failed_days.is_empty(),
        "0%-shift series must stay green for all {days} days; failed: {:?} ({:?})",
        quiet.failed_days,
        quiet.gate_by_day
    );
    let mut gated_days = 0;
    for (day, verdict, extra) in &quiet.gate_by_day {
        if verdict != "no-baseline" {
            gated_days += 1;
            assert_eq!(
                *extra,
                control.expected_min_extra(),
                "day {day}: control must spend exactly the adaptive minimum"
            );
        }
    }
    assert!(gated_days >= days - 5, "gate must be armed for most days");
    println!(
        "control: {days} days green, {gated_days} gated days at exactly {} extra rep(s) each",
        control.expected_min_extra()
    );

    // ---- timed cases --------------------------------------------------
    let mut b = Bench::quick();
    let store = world.repo(&sc.app).unwrap().store.clone();
    b.throughput_case(
        "history: reconstruct 30-day campaign series",
        values.len() as f64,
        "points",
        || History::from_store(&store, "exacb.data", "", &["runtime"]),
    );

    let det = Detector::default();
    let mut rng = Prng::new(7);
    let baseline: Vec<f64> = (0..10).map(|_| rng.normal(60.0, 0.5)).collect();
    let candidate: Vec<f64> = (0..5).map(|_| rng.normal(61.0, 0.5)).collect();
    b.case("detect: welch classify (10 vs 5)", || {
        det.classify(&baseline, &candidate)
    });

    let year: Vec<f64> = (0..365).map(|i| 60.0 + (i % 7) as f64 * 0.05).collect();
    b.throughput_case("detect: annotate 365-point series", 365.0, "points", || {
        det.annotate(&year, 10)
    });
    b.throughput_case("detect: segment 365-point series", 365.0, "points", || {
        tracking::segment(&year, 5.0)
    });
    b.report("perf_tracking");
    println!("\nall tracking contracts held");
}
