//! Perf: data-store append/read (DESIGN.md §8 target: O(1)-ish append
//! per report) and prefix retrieval at campaign scale.

use exacb::bench::Bench;
use exacb::store::DataStore;
use exacb::util::timeutil::SimTime;

fn seeded_store(commits: usize) -> DataStore {
    let mut s = DataStore::new();
    for i in 0..commits {
        s.commit(
            "exacb.data",
            &[(
                format!("jupiter.app{}/{}/report.json", i % 70, 221_600 + i),
                format!("{{\"version\":3,\"i\":{i}}}"),
            )],
            &format!("record {i}"),
            SimTime(i as i64 * 86_400),
        );
    }
    s
}

fn main() {
    let mut b = Bench::new();
    let report = "x".repeat(4096);

    // append cost at three store sizes — how O(1) is it really?
    // (one growing store per size class; appends mutate it in place)
    for size in [10usize, 1000, 10_000] {
        let mut store = seeded_store(size);
        let mut i = 0u64;
        b.case(&format!("append to store of {size} commits"), || {
            i += 1;
            store.commit(
                "exacb.data",
                &[(format!("new/report-{i}.json"), report.clone())],
                "m",
                SimTime(i as i64),
            )
        });
    }
    let store = seeded_store(10_000);
    b.case("read one path at head (10k commits)", || {
        store
            .read("exacb.data", "jupiter.app3/221603/report.json")
            .unwrap()
            .len()
    });
    b.throughput_case(
        "prefix list one app's 143 reports",
        143.0,
        "paths",
        || store.list("exacb.data", "jupiter.app3/"),
    );
    b.case("history walk (10k commits)", || {
        store.history("exacb.data").len()
    });
    b.report("perf_store");
}
