"""AOT compile path: lower L2 models (with embedded L1 Pallas kernels) to
HLO *text* artifacts for the Rust PJRT runtime.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` crate binds) rejects via ``proto.id() <= INT_MAX``. The HLO text
parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md and gen_hlo.py there.

Outputs (under --out-dir, default ../artifacts):
  logmap_i{I}_n{N}.hlo.txt    logistic-map variants (intensity x workload)
  stream_n{N}.hlo.txt         BabelStream checksum model
  manifest.json               machine-readable index consumed by
                              rust/src/runtime/manifest.rs

Run via ``make artifacts`` (no-op when inputs are unchanged). Python never
runs on the Rust request path.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import logmap as lk
from compile.kernels import stream as sk

# Variant grid. Intensity maps the paper's continuous --intensity knob to
# static loop trip counts (fori_loop bounds must be static to lower).
LOGMAP_ITERS = [128, 512, 2048]
LOGMAP_SIZES = [16384, 65536]
STREAM_SIZES = [262144]
MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_logmap(n: int, iters: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    fn = functools.partial(model.logmap_model, iters=iters)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def lower_stream(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    return to_hlo_text(jax.jit(model.stream_model).lower(spec))


def logmap_entry(n: int, iters: int, fname: str) -> dict:
    return {
        "name": f"logmap_i{iters}_n{n}",
        "file": fname,
        "kind": "logmap",
        "params": {"n": n, "iters": iters, "block": lk.DEFAULT_BLOCK},
        "inputs": [
            {"name": "x", "shape": [n], "dtype": "f32"},
            {"name": "r", "shape": [n], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "out", "shape": [n], "dtype": "f32"},
            {"name": "summary", "shape": [4], "dtype": "f32"},
        ],
        "flops": lk.logmap_flops(n, iters),
        "bytes": lk.logmap_bytes(n),
    }


def stream_entry(n: int, fname: str) -> dict:
    return {
        "name": f"stream_n{n}",
        "file": fname,
        "kind": "stream",
        "params": {"n": n, "scalar": 0.4, "block": sk.DEFAULT_BLOCK},
        "inputs": [{"name": "a", "shape": [n], "dtype": "f32"}],
        "outputs": [{"name": "checksums", "shape": [5], "dtype": "f32"}],
        # Total traffic for the 5-kernel sequence (BabelStream accounting).
        "bytes": sum(sk.stream_bytes(n, k)
                     for k in ("copy", "mul", "add", "triad", "dot")),
        "flops": 4 * n,  # mul + add + triad(2) per element, dot counted in bytes
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    # Back-compat with the original Makefile single-file interface.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    entries = []
    for n in LOGMAP_SIZES:
        for iters in LOGMAP_ITERS:
            fname = f"logmap_i{iters}_n{n}.hlo.txt"
            text = lower_logmap(n, iters)
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entries.append(logmap_entry(n, iters, fname))
            print(f"wrote {fname} ({len(text)} chars)")

    for n in STREAM_SIZES:
        fname = f"stream_n{n}.hlo.txt"
        text = lower_stream(n)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(stream_entry(n, fname))
        print(f"wrote {fname} ({len(text)} chars)")

    manifest = {"version": MANIFEST_VERSION, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(entries)} artifacts)")

    if args.out:
        # Legacy sentinel target: symlink the first logmap variant.
        first = os.path.join(out_dir, entries[0]["file"])
        if os.path.abspath(first) != os.path.abspath(args.out):
            if os.path.lexists(args.out):
                os.remove(args.out)
            os.symlink(os.path.basename(first), args.out)


if __name__ == "__main__":
    main()
