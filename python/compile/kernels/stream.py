"""L1 Pallas kernels: BabelStream-style memory-bandwidth kernels.

The paper's Fig. 3 tracks the five BabelStream kernels (Copy, Mul, Add,
Triad, Dot) on JUPITER over time. GPU BabelStream saturates HBM with
coalesced warps; the Pallas adaptation expresses the same streaming
schedule as a 1-D grid of VMEM blocks (DESIGN.md §Hardware-Adaptation):
each block is one HBM->VMEM->HBM pass, so measured bytes/time is the
attainable bandwidth on the executing backend.

Dot is the interesting one: a grid-wide reduction. We emit per-block
partial sums (the Pallas analogue of BabelStream's per-threadblock
reduction buffer) and the L2 model finishes with a jnp.sum — mirroring
the GPU's second reduction kernel.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 16384


def _copy_kernel(a_ref, c_ref):
    c_ref[...] = a_ref[...]


def _mul_kernel(c_ref, b_ref, *, scalar: float):
    b_ref[...] = scalar * c_ref[...]


def _add_kernel(a_ref, b_ref, c_ref):
    c_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(b_ref, c_ref, a_ref, *, scalar: float):
    a_ref[...] = b_ref[...] + scalar * c_ref[...]


def _dot_kernel(a_ref, b_ref, o_ref):
    # Per-block partial sum; the final cross-block reduction happens in L2.
    o_ref[0] = jnp.sum(a_ref[...] * b_ref[...])


def _grid_and_spec(n: int, block: int):
    if n % block != 0:
        raise ValueError(f"N={n} not a multiple of block={block}")
    return (n // block,), pl.BlockSpec((block,), lambda i: (i,))


def stream_copy(a, *, block: int = DEFAULT_BLOCK):
    """c[i] = a[i]; 2 * N * 4 bytes of HBM traffic."""
    grid, spec = _grid_and_spec(a.shape[0], block)
    return pl.pallas_call(
        _copy_kernel, grid=grid, in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype), interpret=True,
    )(a)


def stream_mul(c, scalar: float = 0.4, *, block: int = DEFAULT_BLOCK):
    """b[i] = scalar * c[i]."""
    grid, spec = _grid_and_spec(c.shape[0], block)
    return pl.pallas_call(
        partial(_mul_kernel, scalar=scalar), grid=grid, in_specs=[spec],
        out_specs=spec, out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=True,
    )(c)


def stream_add(a, b, *, block: int = DEFAULT_BLOCK):
    """c[i] = a[i] + b[i]; 3 * N * 4 bytes of traffic."""
    grid, spec = _grid_and_spec(a.shape[0], block)
    return pl.pallas_call(
        _add_kernel, grid=grid, in_specs=[spec, spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype), interpret=True,
    )(a, b)


def stream_triad(b, c, scalar: float = 0.4, *, block: int = DEFAULT_BLOCK):
    """a[i] = b[i] + scalar * c[i]; the headline STREAM kernel."""
    grid, spec = _grid_and_spec(b.shape[0], block)
    return pl.pallas_call(
        partial(_triad_kernel, scalar=scalar), grid=grid,
        in_specs=[spec, spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype), interpret=True,
    )(b, c)


def stream_dot_partials(a, b, *, block: int = DEFAULT_BLOCK):
    """Per-block partial sums of a·b, shape f32[n/block]."""
    n = a.shape[0]
    grid, spec = _grid_and_spec(n, block)
    return pl.pallas_call(
        _dot_kernel, grid=grid, in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // block,), a.dtype),
        interpret=True,
    )(a, b)


def stream_bytes(n: int, kernel: str, dtype_bytes: int = 4) -> int:
    """HBM traffic per kernel, matching BabelStream's accounting."""
    arrays = {"copy": 2, "mul": 2, "add": 3, "triad": 3, "dot": 2}[kernel]
    return arrays * n * dtype_bytes
