"""L1 Pallas kernel: the logistic-map benchmark kernel (paper §II-A).

The paper's running example application ``logmap`` computes the logistic
map x_{n+1} = r * x_n * (1 - x_n) over a vector of inputs, with

* ``--workload``  -> vector length N (bytes streamed through HBM), and
* ``--intensity`` -> iterations per element (arithmetic per byte).

GPU original: one thread per element, an arithmetic-heavy inner loop.
TPU/Pallas adaptation (DESIGN.md §Hardware-Adaptation): a 1-D grid over
VMEM-resident blocks; each block is loaded HBM->VMEM once via BlockSpec,
iterated ``iters`` times entirely in VMEM/registers, and written back
once. Intensity therefore scales FLOPs without scaling memory traffic --
the same roofline knob as the CUDA version, expressed as a block schedule
instead of a thread grid.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs on the Rust runtime.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block: 16384 f32 = 64 KiB in, 64 KiB out — comfortably inside a
# TPU core's ~16 MiB VMEM even with double-buffering (DESIGN.md §Perf).
DEFAULT_BLOCK = 16384


def _logmap_block_kernel(x_ref, r_ref, o_ref, *, iters: int):
    """One grid step: iterate the logistic map ``iters`` times in VMEM."""
    x = x_ref[...]
    r = r_ref[...]

    def body(_, x):
        # 2 FLOPs (mul, fused mul-sub) per element per iteration.
        return r * x * (1.0 - x)

    o_ref[...] = jax.lax.fori_loop(0, iters, body, x)


def logmap(x, r, *, iters: int, block: int = DEFAULT_BLOCK):
    """Apply ``iters`` logistic-map steps elementwise.

    Args:
      x: f32[N] initial values in (0, 1). N must be a multiple of ``block``.
      r: f32[N] per-element map parameter (classically in [0, 4]).
      iters: static iteration count (the --intensity knob).
      block: VMEM block length.

    Returns:
      f32[N] final values.
    """
    n = x.shape[0]
    if n % block != 0:
        raise ValueError(f"N={n} not a multiple of block={block}")
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        partial(_logmap_block_kernel, iters=iters),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x, r)


def logmap_flops(n: int, iters: int) -> int:
    """FLOP count for one logmap invocation (3 flops/elem/iter)."""
    return 3 * n * iters


def logmap_bytes(n: int, dtype_bytes: int = 4) -> int:
    """HBM traffic: read x, read r, write out — once each regardless of iters."""
    return 3 * n * dtype_bytes
