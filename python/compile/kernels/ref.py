"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has a reference here written with plain
jax.numpy only — no Pallas, no custom control flow beyond fori_loop.
pytest (python/tests/test_kernel.py) asserts allclose between kernel and
oracle across a hypothesis-driven sweep of shapes, seeds, and parameters.
"""

import jax
import jax.numpy as jnp


def logmap_ref(x, r, *, iters: int):
    """Reference logistic map: iterate x <- r*x*(1-x) ``iters`` times."""

    def body(_, x):
        return r * x * (1.0 - x)

    return jax.lax.fori_loop(0, iters, body, x)


def stream_copy_ref(a):
    return jnp.asarray(a).copy()


def stream_mul_ref(c, scalar: float = 0.4):
    return scalar * c


def stream_add_ref(a, b):
    return a + b


def stream_triad_ref(b, c, scalar: float = 0.4):
    return b + scalar * c


def stream_dot_ref(a, b):
    return jnp.sum(a * b)


def stream_dot_partials_ref(a, b, *, block: int):
    """Per-block partial dot products, matching stream.stream_dot_partials."""
    n = a.shape[0]
    return jnp.sum((a * b).reshape(n // block, block), axis=1)
