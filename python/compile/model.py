"""L2: JAX compute graphs for the benchmark applications.

These are the functions AOT-lowered by aot.py into artifacts/*.hlo.txt and
executed from the Rust runtime (rust/src/runtime). They call the L1 Pallas
kernels so kernel + surrounding graph lower into a single HLO module.

Design notes (DESIGN.md §Perf, L2):
* logmap returns the full output vector: the Rust workload re-computes the
  map in scalar f32 to set the Table-I ``success`` column, then derives
  the logmap.out statistics — so the artifact must not hide the data.
* stream returns only the five checksums (copy/mul/add/triad sums + dot):
  BabelStream validates on-device, and shipping 4x1 MiB back per daily
  pipeline would measure PCIe, not HBM. The checksums have closed forms
  for the constant initialisation Rust uses, giving exact validation.
* No python on the request path: everything below exists only at
  ``make artifacts`` time.
"""

import jax.numpy as jnp

from compile.kernels import logmap as lk
from compile.kernels import stream as sk


def logmap_model(x, r, *, iters: int, block: int = lk.DEFAULT_BLOCK):
    """The logmap application body: kernel + summary statistics.

    Returns:
      out:  f32[N] final iterates (written to ``logmap.out`` by the app).
      summary: f32[4] = [mean, min, max, sum] (the ``logmap.stats`` seed).
    """
    out = lk.logmap(x, r, iters=iters, block=block)
    summary = jnp.stack(
        [jnp.mean(out), jnp.min(out), jnp.max(out), jnp.sum(out)]
    )
    return out, summary


def stream_model(a, *, scalar: float = 0.4, block: int = sk.DEFAULT_BLOCK):
    """One BabelStream iteration: copy, mul, add, triad, dot in sequence.

    Follows BabelStream's dataflow: c<-a, b<-scalar*c, c<-a+b,
    a<-b+scalar*c, sum = a·b. The initial b and c arrays are overwritten
    before first read, so the computation takes only ``a`` (XLA would
    drop unused parameters from the lowered module anyway). Returns
    f32[5] checksums [sum(c'), sum(b'), sum(c''), sum(a'), dot].
    """
    c1 = sk.stream_copy(a, block=block)
    b1 = sk.stream_mul(c1, scalar, block=block)
    c2 = sk.stream_add(a, b1, block=block)
    a1 = sk.stream_triad(b1, c2, scalar, block=block)
    dot = jnp.sum(sk.stream_dot_partials(a1, b1, block=block))
    checksums = jnp.stack(
        [jnp.sum(c1), jnp.sum(b1), jnp.sum(c2), jnp.sum(a1), dot]
    )
    return (checksums,)


def stream_checksums_expected(n: int, a0: float, scalar: float = 0.4):
    """Closed-form expected checksums for constant-initialised arrays.

    Mirrors the Rust-side validator (workloads/stream.rs); kept here so
    python/tests can assert the two implementations agree.
    """
    c1 = a0
    b1 = scalar * c1
    c2 = a0 + b1
    a1 = b1 + scalar * c2
    dot = a1 * b1 * n
    return [n * c1, n * b1, n * c2, n * a1, dot]
