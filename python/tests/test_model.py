"""pytest: L2 model shapes, checksum closed forms, and AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def test_logmap_model_shapes_and_summary():
    n, block, iters = 256, 128, 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    r = jnp.full((n,), 3.5, jnp.float32)
    out, summary = model.logmap_model(x, r, iters=iters, block=block)
    assert out.shape == (n,) and summary.shape == (4,)
    np.testing.assert_allclose(summary[0], jnp.mean(out), rtol=1e-6)
    np.testing.assert_allclose(summary[1], jnp.min(out), rtol=1e-6)
    np.testing.assert_allclose(summary[2], jnp.max(out), rtol=1e-6)
    np.testing.assert_allclose(summary[3], jnp.sum(out), rtol=1e-5)
    want = ref.logmap_ref(x, r, iters=iters)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    a0=st.floats(0.05, 1.0),
    scalar=st.floats(0.1, 1.0),
)
def test_stream_model_matches_closed_form(a0, scalar):
    """Constant-initialised arrays: model checksums == closed form.

    This is the exact validation contract the Rust workload
    (rust/src/workloads/stream.rs) relies on.
    """
    n, block = 256, 128
    a = jnp.full((n,), a0, jnp.float32)
    (got,) = model.stream_model(a, scalar=scalar, block=block)
    want = model.stream_checksums_expected(n, a0, scalar)
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               rtol=2e-4)


def test_stream_model_random_inputs_vs_ref():
    n, block, scalar = 512, 128, 0.4
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.uniform(-1, 1, n).astype(np.float32))
    (got,) = model.stream_model(a, scalar=scalar, block=block)
    c1 = ref.stream_copy_ref(a)
    b1 = ref.stream_mul_ref(c1, scalar)
    c2 = ref.stream_add_ref(a, b1)
    a1 = ref.stream_triad_ref(b1, c2, scalar)
    want = jnp.stack([jnp.sum(c1), jnp.sum(b1), jnp.sum(c2), jnp.sum(a1),
                      ref.stream_dot_ref(a1, b1)])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ AOT

def test_aot_logmap_lowering_produces_hlo_text():
    from compile import aot
    text = aot.lower_logmap(n=16384, iters=2)
    assert "ENTRY" in text and "HloModule" in text
    # while loop from fori_loop must survive lowering
    assert "while" in text


def test_aot_stream_lowering_produces_hlo_text():
    from compile import aot
    text = aot.lower_stream(n=262144)
    assert "ENTRY" in text and "HloModule" in text


def test_manifest_entries_are_consistent():
    from compile import aot
    e = aot.logmap_entry(65536, 512, "f.hlo.txt")
    assert e["flops"] == 3 * 65536 * 512
    assert e["inputs"][0]["shape"] == [65536]
    s = aot.stream_entry(262144, "s.hlo.txt")
    assert s["outputs"][0]["shape"] == [5]
    assert s["bytes"] == (2 + 2 + 3 + 3 + 2) * 262144 * 4
