"""pytest: Pallas kernels vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps block sizes, grid sizes, iteration counts, dtypes and
seeds; every case asserts allclose(kernel, ref). Shapes are kept small so
interpret-mode Pallas (CPU numpy semantics) stays fast.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logmap as lk
from compile.kernels import ref
from compile.kernels import stream as sk

SETTINGS = dict(max_examples=25, deadline=None)


def rng_arrays(seed, n, dtype, lo=0.0, hi=1.0, count=1):
    rng = np.random.default_rng(seed)
    out = [jnp.asarray(rng.uniform(lo, hi, n).astype(dtype))
           for _ in range(count)]
    return out[0] if count == 1 else out


# ---------------------------------------------------------------- logmap

@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 4),
    block=st.sampled_from([64, 128, 256]),
    iters=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_logmap_matches_ref(nblocks, block, iters, seed):
    n = nblocks * block
    x, r = rng_arrays(seed, n, np.float32, count=2)
    r = 4.0 * r  # classic logistic-map parameter range [0, 4)
    got = lk.logmap(x, r, iters=iters, block=block)
    want = ref.logmap_ref(x, r, iters=iters)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype,rtol", [(np.float32, 1e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_logmap_dtypes(dtype, rtol):
    n, block, iters = 256, 128, 8
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.uniform(0, 1, n), dtype=dtype)
    r = jnp.asarray(rng.uniform(0, 4, n), dtype=dtype)
    got = lk.logmap(x, r, iters=iters, block=block)
    want = ref.logmap_ref(x, r, iters=iters)
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), rtol=rtol)


def test_logmap_production_variant_shape():
    """The AOT variants (n=16384, block=16384) lower and run."""
    n, iters = 16384, 128
    x, r = rng_arrays(3, n, np.float32, count=2)
    out = lk.logmap(x, 3.7 * r, iters=iters)
    assert out.shape == (n,)
    want = ref.logmap_ref(x, 3.7 * r, iters=iters)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


def test_logmap_fixed_point():
    """x=0 and x=1-1/r are fixed points of the map."""
    block = 64
    r = jnp.full((block,), 3.2, jnp.float32)
    zero = jnp.zeros((block,), jnp.float32)
    np.testing.assert_allclose(
        lk.logmap(zero, r, iters=17, block=block), zero, atol=0)
    fp = 1.0 - 1.0 / r
    got = lk.logmap(fp, r, iters=17, block=block)
    np.testing.assert_allclose(got, fp, rtol=1e-4)


def test_logmap_rejects_ragged_block():
    x = jnp.zeros((100,), jnp.float32)
    with pytest.raises(ValueError):
        lk.logmap(x, x, iters=1, block=64)


def test_logmap_flops_bytes_accounting():
    assert lk.logmap_flops(1000, 10) == 3 * 1000 * 10
    assert lk.logmap_bytes(1000) == 3 * 1000 * 4


# ---------------------------------------------------------------- stream

@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 4),
    block=st.sampled_from([64, 128]),
    scalar=st.floats(0.1, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_stream_kernels_match_ref(nblocks, block, scalar, seed):
    n = nblocks * block
    a, b, c = rng_arrays(seed, n, np.float32, -1.0, 1.0, count=3)
    np.testing.assert_allclose(
        sk.stream_copy(a, block=block), ref.stream_copy_ref(a))
    np.testing.assert_allclose(
        sk.stream_mul(c, scalar, block=block),
        ref.stream_mul_ref(c, scalar), rtol=1e-6)
    np.testing.assert_allclose(
        sk.stream_add(a, b, block=block), ref.stream_add_ref(a, b),
        rtol=1e-6)
    # triad may fuse b + scalar*c into an FMA in one impl but not the other
    np.testing.assert_allclose(
        sk.stream_triad(b, c, scalar, block=block),
        ref.stream_triad_ref(b, c, scalar), rtol=1e-5, atol=1e-7)


@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 8),
    block=st.sampled_from([64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_stream_dot_partials_match_ref(nblocks, block, seed):
    n = nblocks * block
    a, b = rng_arrays(seed, n, np.float32, -1.0, 1.0, count=2)
    got = sk.stream_dot_partials(a, b, block=block)
    assert got.shape == (nblocks,)
    want = ref.stream_dot_partials_ref(a, b, block=block)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(jnp.sum(got), ref.stream_dot_ref(a, b),
                               rtol=1e-4, atol=1e-5)


def test_stream_bytes_accounting():
    assert sk.stream_bytes(1000, "copy") == 2 * 4000
    assert sk.stream_bytes(1000, "add") == 3 * 4000
    assert sk.stream_bytes(1000, "triad") == 3 * 4000
    with pytest.raises(KeyError):
        sk.stream_bytes(1000, "nope")


def test_stream_copy_is_identity_not_alias():
    a = jnp.arange(128, dtype=jnp.float32)
    out = sk.stream_copy(a, block=64)
    np.testing.assert_array_equal(out, a)
