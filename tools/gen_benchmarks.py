#!/usr/bin/env python3
"""Regenerate the shipped `benchmarks/` definition directory.

This is a bit-exact Python port of the crate's built-in definition set
(`exacb::defs::builtin()` rendered through `exacb::defs::render()`):

- `rust/src/util/prng.rs` — splitmix64 seeding + xoshiro256** + Lemire
  bounded draw, reproduced with explicit 64-bit wrapping arithmetic.
- `rust/src/workloads/portfolio.rs::generate(72, 20260101)` — the
  JUREAP-like portfolio, drawn in exactly the same order.
- `rust/src/cluster/{machine,network,power}.rs` — the four standard
  machines with full network and power fingerprints.

The Rust test-suite proves equivalence from the other side:
`tests/integration_defs.rs` loads `benchmarks/` and asserts the parsed
`DefSet` equals `defs::builtin()` (f64 bit equality), then replays a
campaign and compares sacct records, stores, and result tables against
the code path. If you edit the built-in set, rerun

    python3 tools/gen_benchmarks.py

from the repository root and commit the regenerated files.

Float formatting note: Python's repr() and Rust's `{:?}` both emit the
shortest decimal that round-trips, so digits agree; only the exponent
spelling differs (`8.7e-05` vs `8.7e-5`), which `fmt_f64` normalises.
"""

import os
import sys

MASK = (1 << 64) - 1
GOLDEN = 0x9E37_79B9_7F4A_7C15


class Prng:
    """xoshiro256** seeded via splitmix64 (port of util::prng::Prng)."""

    def __init__(self, seed):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s = (s + GOLDEN) & MASK
            z = s
            z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK
            self.s.append(z ^ (z >> 31))

    @staticmethod
    def _rotl(x, k):
        return ((x << k) | (x >> (64 - k))) & MASK

    def next_u64(self):
        s = self.s
        result = (self._rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = self._rotl(s[3], 45)
        return result

    def fork(self, tag):
        return Prng(self.next_u64() ^ ((tag * GOLDEN) & MASK))

    def f64(self):
        # (x >> 11) as f64 * (1 / 2^53): both factors exact in binary64.
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def range_f64(self, lo, hi):
        return lo + (hi - lo) * self.f64()

    def below(self, n):
        assert n > 0
        x = self.next_u64()
        m = x * n
        l = m & MASK
        if l < n:
            t = ((1 << 64) - n) % n
            while l < t:
                x = self.next_u64()
                m = x * n
                l = m & MASK
        return m >> 64

    def range_u64(self, lo, hi):
        return lo + self.below(hi - lo + 1)


DOMAINS = [
    "climate",
    "molecular-dynamics",
    "lattice-qcd",
    "cfd",
    "neuroscience",
    "materials",
    "astrophysics",
    "ai-training",
]


def generate(n, seed):
    """Port of workloads::portfolio::generate — same draw order."""
    rng = Prng(seed)
    apps = []
    for i in range(n):
        domain = DOMAINS[i % len(DOMAINS)]
        app_rng = rng.fork(i)
        p = app_rng.f64()
        if p < 0.40:
            maturity = "runnability"
        elif p < 0.80:
            maturity = "instrumentability"
        else:
            maturity = "reproducibility"
        mem_bound = app_rng.range_f64(0.15, 0.9)
        gflops_total = app_rng.range_f64(5_000.0, 500_000.0)
        serial_frac = app_rng.range_f64(0.002, 0.08)
        comm_mb = app_rng.range_f64(4.0, 256.0)
        steps = app_rng.range_u64(20, 400)
        if maturity == "runnability":
            failure_rate = app_rng.range_f64(0.05, 0.20)
        elif maturity == "instrumentability":
            failure_rate = app_rng.range_f64(0.02, 0.08)
        else:
            failure_rate = app_rng.range_f64(0.0, 0.03)
        nodes = 1 << app_rng.range_u64(0, 4)
        apps.append(
            {
                "name": "%s-%02d" % (domain, i + 1),
                "domain": domain,
                "maturity": maturity,
                "nodes": nodes,
                "gflops_total": gflops_total,
                "serial_frac": serial_frac,
                "mem_bound": mem_bound,
                "comm_mb": comm_mb,
                "steps": steps,
                "failure_rate": failure_rate,
            }
        )
    return apps


def fmt_f64(v):
    """Shortest round-trip decimal, Rust `{:?}` exponent spelling."""
    s = repr(float(v))
    if "e" in s:
        mant, exp = s.split("e")
        s = "%se%d" % (mant, int(exp))
    return s


def toml_str(s):
    out = s.replace("\\", "\\\\").replace('"', '\\"')
    out = out.replace("\n", "\\n").replace("\t", "\\t")
    return '"%s"' % out


def str_list(items):
    return "[%s]" % ", ".join(toml_str(s) for s in items)


NETWORKS = {
    "ndr400": ("IB-NDR400", 0.9, 48.0, 2.2, 0.55, 0.012, 8192),
    "hdr200": ("IB-HDR200", 1.1, 24.0, 2.6, 0.55, 0.02, 8192),
    "hdr100": ("IB-HDR100", 1.2, 12.0, 2.8, 0.55, 0.03, 8192),
}

POWER = {
    "a100": (55.0, 400.0, 1410.0, 210.0, 4.0),
    "gh200": (75.0, 700.0, 1980.0, 345.0, 6.0),
}

# (name, version, gpu, nodes, gpus/node, cores/node, partitions,
#  network preset, power preset, stream_eff, noise_sigma, perf_factor)
MACHINES = [
    ("jedi", "2026.1", "gh200", 48, 4, 288, ["all", "devel"],
     "ndr400", "gh200", 0.855, 0.006, 3.35),
    ("jupiter", "2026.1", "gh200", 5888, 4, 288, ["booster", "devel", "all"],
     "ndr400", "gh200", 0.855, 0.006, 3.35),
    ("juwels-booster", "2024.3", "ampere", 936, 4, 96,
     ["booster", "develbooster"], "hdr200", "a100", 0.87, 0.008, 1.0),
    ("jureca", "2024.3", "ampere", 192, 4, 128,
     ["dc-gpu", "dc-gpu-devel", "all"], "hdr100", "a100", 0.86, 0.010, 0.97),
]


def render_engines():
    out = ["# Engines: labelled harness commands "
           "(generated from the built-in set).\n"]
    out.append(
        "\n[[engine]]\nname = %s\ncommand = %s\ndescription = %s\n"
        % (
            toml_str("simapp"),
            toml_str("simapp"),
            toml_str("parameterised scalable application (workloads::scalable)"),
        )
    )
    return "".join(out)


def render_apps(apps):
    out = [
        "# The JUREAP-like 72-app portfolio as data. App order is semantic:\n"
        "# it drives machine assignment and the seeded daily shuffle, so\n"
        "# this file lists apps in exactly the built-in portfolio order.\n"
    ]
    for a in apps:
        out.append(
            "\n[[app]]\nname = %s\ndomain = %s\nmaturity = %s\n"
            "engine = %s\nnodes = %d\n\n"
            "[app.parameters]\ngflops_total = %s\nserial_frac = %s\n"
            "mem_bound = %s\ncomm_mb = %s\nsteps = %d\nweak = false\n\n"
            "[app.behavior]\nfailure_rate = %s\n\n"
            "[app.metrics]\nprimary = %s\nrecord = %s\n"
            % (
                toml_str(a["name"]),
                toml_str(a["domain"]),
                toml_str(a["maturity"]),
                toml_str("simapp"),
                a["nodes"],
                fmt_f64(a["gflops_total"]),
                fmt_f64(a["serial_frac"]),
                fmt_f64(a["mem_bound"]),
                fmt_f64(a["comm_mb"]),
                a["steps"],
                fmt_f64(a["failure_rate"]),
                toml_str("tts"),
                str_list(["tts", "gflops_rate"]),
            )
        )
    return "".join(out)


def render_machines():
    out = [
        "# The four standard JSC-like systems with full network and power\n"
        '# fingerprints (presets like network = "ndr400" also work).\n'
    ]
    for (name, version, gpu, nodes, gpn, cpn, parts,
         net, pwr, se, ns, pf) in MACHINES:
        nname, lat, bw, hs, ebf, ekb, thresh = NETWORKS[net]
        idle, tdp, nom, mn, snw = POWER[pwr]
        out.append(
            "\n[[machine]]\nname = %s\nversion = %s\ngpu = %s\n"
            "nodes = %d\ngpus_per_node = %d\ncores_per_node = %d\n"
            "partitions = %s\nstream_efficiency = %s\nnoise_sigma = %s\n"
            "perf_factor = %s\n\n"
            "[machine.network]\nname = %s\nlatency_us = %s\nbw_gbs = %s\n"
            "rndv_handshake_us = %s\neager_bw_fraction = %s\n"
            "eager_per_kb_us = %s\ndefault_rndv_thresh = %d\n\n"
            "[machine.power]\nidle_w = %s\ntdp_w = %s\nnominal_mhz = %s\n"
            "min_mhz = %s\nsensor_noise_w = %s\n"
            % (
                toml_str(name), toml_str(version), toml_str(gpu),
                nodes, gpn, cpn,
                str_list(parts), fmt_f64(se), fmt_f64(ns), fmt_f64(pf),
                toml_str(nname), fmt_f64(lat), fmt_f64(bw),
                fmt_f64(hs), fmt_f64(ebf), fmt_f64(ekb), thresh,
                fmt_f64(idle), fmt_f64(tdp), fmt_f64(nom),
                fmt_f64(mn), fmt_f64(snw),
            )
        )
    return "".join(out)


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out_dir = os.path.join(root, "benchmarks")
    os.makedirs(out_dir, exist_ok=True)
    apps = generate(72, 20260101)
    files = {
        "engines.toml": render_engines(),
        "jureap.toml": render_apps(apps),
        "machines.toml": render_machines(),
    }
    for name, contents in sorted(files.items()):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(contents)
        print("wrote %s (%d bytes)" % (path, len(contents)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
